//! Binary array and adjacency-shard files of a partition bundle.
//!
//! Every file carries an 8-byte magic plus explicit element counts, and
//! every reader checks the *exact* expected file size before touching
//! the payload, so truncated, extended, or bit-flipped input surfaces as
//! an [`Error`] — never a panic or a silent misread (the hardening
//! contract of the persist subsystem, exercised by
//! `tests/test_persist_corruption.rs`).
//!
//! Adjacency shards additionally carry an **identity stamp**
//! (`edge_type index, partition` — the `.pyga` analog of the feature
//! shards' `__bundle_shard` group, so a tampered manifest cannot
//! re-point a shard slot at another partition's structurally valid
//! file) and an **FNV-1a payload checksum**. The checksum lets the
//! demand-paged reader ([`crate::persist::PagedAdjacency`]) reject any
//! payload corruption *at open* with one streaming pass and O(1)
//! memory, without decoding the shard — the same every-byte-flip
//! guarantee the resident path gets from its full structural
//! cross-validation.

use crate::error::{Error, Result};
use crate::graph::Compressed;
use crate::obs;
use crate::storage::pread_raw;
use std::fs::File;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

const U32_MAGIC: &[u8; 8] = b"PYGU32A1";
const I64_MAGIC: &[u8; 8] = b"PYGI64A1";
pub(crate) const ADJ_MAGIC: &[u8; 8] = b"PYGADJ2\0";

/// Bytes of an adjacency shard header: magic + `(et_index, partition,
/// n_src, n_dst, csc_nnz, csr_nnz, payload_hash)` as u64 LE.
pub(crate) const ADJ_HEADER_BYTES: u64 = 8 + 7 * 8;

pub(crate) fn bad(path: &Path, what: &str) -> Error {
    Error::Storage(format!("{}: {what}", path.display()))
}

/// Streaming FNV-1a over byte chunks (64-bit).
pub(crate) struct Fnv1a(u64);

impl Fnv1a {
    #[allow(clippy::new_without_default)]
    pub(crate) fn new() -> Self {
        Self(0xcbf2_9ce4_8422_2325)
    }

    pub(crate) fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub(crate) fn finish(&self) -> u64 {
        self.0
    }
}

/// One segment of a batched positioned read: fill `buf` from byte
/// `offset` of the source.
pub struct IoSeg<'a> {
    pub offset: u64,
    pub buf: &'a mut [u8],
}

/// How a read-only, checksum-validated shard issues positioned I/O —
/// the single seam every demand-paged reader
/// ([`crate::persist::PagedFeatureStore`] /
/// [`crate::persist::PagedAdjacency`] / [`crate::persist::PagedEdgeTime`])
/// reads through, so the pread-vs-mmap choice is one swappable
/// implementation and coalesced runs within one shard touch can go down
/// as one batched submission.
pub trait PageSource: Send + Sync {
    /// Read exactly `buf.len()` bytes at `offset`.
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()>;

    /// One batched submission of several positioned segments. The
    /// default serves each segment with [`PageSource::read_at`];
    /// implementations with cheaper per-segment cost (mmap: a memcpy,
    /// no syscall) inherit it for free.
    fn read_batch(&self, segs: &mut [IoSeg<'_>]) -> Result<()> {
        for seg in segs {
            self.read_at(seg.offset, seg.buf)?;
        }
        Ok(())
    }

    /// Total byte length of the backing file.
    fn len(&self) -> u64;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The backing file's path (error messages).
    fn path(&self) -> &Path;
}

/// Which [`PageSource`] implementation a mount issues its demand-paged
/// reads through (`--io-backend`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum IoBackend {
    /// Positioned `pread` syscalls (lock-free on Unix). The default:
    /// works everywhere, never faults, and the kernel page cache still
    /// absorbs re-reads.
    #[default]
    Pread,
    /// Map the whole shard read-only and serve reads as memcpys — no
    /// per-miss syscall. Only for shards that are immutable while
    /// mounted: the open-time checksum validates the bytes once, but a
    /// file truncated *after* mapping faults instead of erroring.
    Mmap,
}

impl IoBackend {
    /// Parse a `--io-backend` value.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "pread" => Ok(Self::Pread),
            "mmap" => Ok(Self::Mmap),
            other => Err(Error::Config(format!(
                "unknown io backend {other:?} (expected pread or mmap)"
            ))),
        }
    }
}

impl std::fmt::Display for IoBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Pread => "pread",
            Self::Mmap => "mmap",
        })
    }
}

/// The default [`PageSource`]: positioned `pread`s against an open file
/// (a seek-lock fallback keeps non-Unix hosts correct).
pub struct PreadSource {
    file: File,
    path: PathBuf,
    len: u64,
    #[cfg(not(unix))]
    seek_lock: std::sync::Mutex<()>,
}

impl PreadSource {
    /// Wrap an already-open (and already-validated) file handle. The
    /// file cursor is not used — positioned reads only.
    pub fn new(file: File, path: PathBuf) -> Result<Self> {
        let len = file.metadata()?.len();
        Ok(Self {
            file,
            path,
            len,
            #[cfg(not(unix))]
            seek_lock: std::sync::Mutex::new(()),
        })
    }
}

impl PageSource for PreadSource {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        #[cfg(unix)]
        {
            pread_raw(&self.file, offset, buf)
        }
        #[cfg(not(unix))]
        {
            let _guard = self.seek_lock.lock().unwrap();
            pread_raw(&self.file, offset, buf)
        }
    }

    fn len(&self) -> u64 {
        self.len
    }

    fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(unix)]
mod mmap_sys {
    use std::ffi::c_void;
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
    /// Identical on Linux and the BSDs/macOS.
    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;
}

/// Read-only `mmap` [`PageSource`]: the whole shard is mapped private
/// and every read is a bounds-checked memcpy. See [`IoBackend::Mmap`]
/// for the immutability caveat.
#[cfg(unix)]
pub struct MmapSource {
    ptr: *const u8,
    len: usize,
    path: PathBuf,
    /// Held so the descriptor outlives the mapping (not strictly
    /// required by POSIX, but keeps `/proc` maps attributable).
    _file: File,
}

// The mapping is immutable after construction; concurrent reads of the
// mapped bytes are safe.
#[cfg(unix)]
unsafe impl Send for MmapSource {}
#[cfg(unix)]
unsafe impl Sync for MmapSource {}

#[cfg(unix)]
impl MmapSource {
    pub fn new(file: File, path: PathBuf) -> Result<Self> {
        use std::os::unix::io::AsRawFd;
        let len = file.metadata()?.len() as usize;
        if len == 0 {
            // mmap(len=0) is EINVAL; an empty source serves no reads.
            return Ok(Self { ptr: std::ptr::null(), len: 0, path, _file: file });
        }
        let ptr = unsafe {
            mmap_sys::mmap(
                std::ptr::null_mut(),
                len,
                mmap_sys::PROT_READ,
                mmap_sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(bad(&path, "mmap failed"));
        }
        Ok(Self { ptr: ptr as *const u8, len, path, _file: file })
    }

    fn bytes(&self) -> &[u8] {
        if self.len == 0 {
            &[]
        } else {
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        }
    }
}

#[cfg(unix)]
impl Drop for MmapSource {
    fn drop(&mut self) {
        if self.len > 0 {
            unsafe {
                mmap_sys::munmap(self.ptr as *mut std::ffi::c_void, self.len);
            }
        }
    }
}

#[cfg(unix)]
impl PageSource for MmapSource {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        let end = offset as usize + buf.len();
        if end > self.len {
            return Err(bad(
                &self.path,
                &format!("read of {end} bytes past the {}-byte mapping", self.len),
            ));
        }
        buf.copy_from_slice(&self.bytes()[offset as usize..end]);
        Ok(())
    }

    fn len(&self) -> u64 {
        self.len as u64
    }

    fn path(&self) -> &Path {
        &self.path
    }
}

/// [`PageSource`] decorator accounting every positioned read into the
/// shared `persist.io.*` registry metrics: single reads, batched
/// submissions and their segments (coalesced runs), bytes moved, and —
/// only while telemetry is enabled — a per-call latency histogram.
/// Every source built by [`page_source`] is wrapped, so all shard files
/// of a mount aggregate into one ledger; with telemetry disabled a read
/// costs two relaxed counter adds and no clock read.
struct ObservedSource {
    inner: Arc<dyn PageSource>,
    reads: Arc<obs::Counter>,
    batch_calls: Arc<obs::Counter>,
    batched_runs: Arc<obs::Counter>,
    bytes: Arc<obs::Counter>,
    read_us: Arc<obs::Histogram>,
}

impl ObservedSource {
    fn new(inner: Arc<dyn PageSource>) -> Self {
        Self {
            inner,
            reads: obs::counter("persist.io.reads"),
            batch_calls: obs::counter("persist.io.batch_calls"),
            batched_runs: obs::counter("persist.io.batched_runs"),
            bytes: obs::counter("persist.io.bytes"),
            read_us: obs::histogram("persist.io.read_us"),
        }
    }
}

impl PageSource for ObservedSource {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        let started = obs::enabled().then(Instant::now);
        self.inner.read_at(offset, buf)?;
        self.reads.inc();
        self.bytes.add(buf.len() as u64);
        if let Some(t) = started {
            self.read_us.record(t.elapsed().as_micros() as u64);
        }
        Ok(())
    }

    fn read_batch(&self, segs: &mut [IoSeg<'_>]) -> Result<()> {
        let started = obs::enabled().then(Instant::now);
        self.inner.read_batch(segs)?;
        self.batch_calls.inc();
        self.batched_runs.add(segs.len() as u64);
        self.bytes.add(segs.iter().map(|s| s.buf.len() as u64).sum());
        if let Some(t) = started {
            self.read_us.record(t.elapsed().as_micros() as u64);
        }
        Ok(())
    }

    fn len(&self) -> u64 {
        self.inner.len()
    }

    fn path(&self) -> &Path {
        self.inner.path()
    }
}

/// Wrap an already-open, already-validated shard file in the chosen
/// [`PageSource`] backend (plus the `persist.io.*` accounting
/// decorator).
pub fn page_source(file: File, path: PathBuf, backend: IoBackend) -> Result<Arc<dyn PageSource>> {
    let raw: Arc<dyn PageSource> = match backend {
        IoBackend::Pread => Arc::new(PreadSource::new(file, path)?),
        #[cfg(unix)]
        IoBackend::Mmap => Arc::new(MmapSource::new(file, path)?),
        #[cfg(not(unix))]
        IoBackend::Mmap => {
            return Err(Error::Config(
                "the mmap io backend is only available on Unix hosts".into(),
            ))
        }
    };
    Ok(Arc::new(ObservedSource::new(raw)))
}

/// Read a whole file, verifying its magic and exact length:
/// `16 + count * elem_size` where `count` is the u64 after the magic.
fn read_sized(path: &Path, magic: &[u8; 8], elem_size: u64) -> Result<(u64, Vec<u8>)> {
    let mut f = File::open(path)?;
    let file_len = f.metadata()?.len();
    if file_len < 16 {
        return Err(bad(path, "too short for a bundle array file"));
    }
    let mut head = [0u8; 16];
    f.read_exact(&mut head)?;
    if &head[..8] != magic {
        return Err(bad(path, "bad magic"));
    }
    let count = u64::from_le_bytes(head[8..16].try_into().unwrap());
    let expect = 16u128 + count as u128 * elem_size as u128;
    if expect != file_len as u128 {
        return Err(bad(
            path,
            &format!("claims {count} elements ({expect} bytes) but holds {file_len}"),
        ));
    }
    let mut data = vec![0u8; (file_len - 16) as usize];
    f.read_exact(&mut data)?;
    Ok((count, data))
}

fn write_sized(path: &Path, magic: &[u8; 8], count: u64, payload: &[u8]) -> Result<()> {
    let mut f = File::create(path)?;
    f.write_all(magic)?;
    f.write_all(&count.to_le_bytes())?;
    f.write_all(payload)?;
    f.sync_all()?;
    Ok(())
}

/// Write a `u32` array file (ownership vectors).
pub fn write_u32_array(path: &Path, data: &[u32]) -> Result<()> {
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    write_sized(path, U32_MAGIC, data.len() as u64, &bytes)
}

/// Read a `u32` array file, verifying magic and exact size.
pub fn read_u32_array(path: &Path) -> Result<Vec<u32>> {
    let (_, data) = read_sized(path, U32_MAGIC, 4)?;
    Ok(data
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Write an `i64` array file (labels, timestamps).
pub fn write_i64_array(path: &Path, data: &[i64]) -> Result<()> {
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    write_sized(path, I64_MAGIC, data.len() as u64, &bytes)
}

/// Read an `i64` array file, verifying magic and exact size.
pub fn read_i64_array(path: &Path) -> Result<Vec<i64>> {
    let (_, data) = read_sized(path, I64_MAGIC, 8)?;
    Ok(data
        .chunks_exact(8)
        .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Open an `i64` array file for positioned reads: validate magic and
/// exact size, return `(file, count)` with the payload untouched — the
/// demand-paged edge-time path ([`crate::persist::PagedEdgeTime`]).
pub(crate) fn open_i64_array(path: &Path) -> Result<(File, usize)> {
    let mut f = File::open(path)?;
    let file_len = f.metadata()?.len();
    if file_len < 16 {
        return Err(bad(path, "too short for a bundle array file"));
    }
    let mut head = [0u8; 16];
    f.read_exact(&mut head)?;
    if &head[..8] != I64_MAGIC {
        return Err(bad(path, "bad magic"));
    }
    let count = u64::from_le_bytes(head[8..16].try_into().unwrap());
    if 16u128 + count as u128 * 8 != file_len as u128 {
        return Err(bad(
            path,
            &format!("claims {count} elements but holds {file_len} bytes"),
        ));
    }
    Ok((f, count as usize))
}

/// Identity stamp of one adjacency shard: which `(edge type, partition)`
/// slot of the bundle this file is. Verified on every open (resident
/// and paged), so re-pointed shards fail before any neighbor list is
/// served.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AdjStamp {
    pub et_index: u64,
    pub partition: u64,
}

/// Parsed header + byte offsets of one adjacency shard file — the
/// shared layout contract between the writer, the resident reader and
/// the demand-paged reader.
#[derive(Clone, Copy, Debug)]
pub(crate) struct AdjLayout {
    pub stamp: AdjStamp,
    pub n_src: usize,
    pub n_dst: usize,
    pub csc_nnz: usize,
    pub csr_nnz: usize,
    pub payload_hash: u64,
    pub file_len: u64,
}

impl AdjLayout {
    /// Byte offset of the CSC `indptr` array (`n_dst + 1` u64).
    pub fn csc_indptr_off(&self) -> u64 {
        ADJ_HEADER_BYTES
    }

    /// Byte offset of the CSC `indices` array (`csc_nnz` u32).
    pub fn csc_indices_off(&self) -> u64 {
        self.csc_indptr_off() + (self.n_dst as u64 + 1) * 8
    }

    /// Byte offset of the CSC `perm` array (`csc_nnz` u32).
    pub fn csc_perm_off(&self) -> u64 {
        self.csc_indices_off() + self.csc_nnz as u64 * 4
    }

    /// Byte offset of the CSR `indptr` array (`n_src + 1` u64).
    pub fn csr_indptr_off(&self) -> u64 {
        self.csc_perm_off() + self.csc_nnz as u64 * 4
    }

    /// Byte offset of the CSR `indices` array (`csr_nnz` u32).
    pub fn csr_indices_off(&self) -> u64 {
        self.csr_indptr_off() + (self.n_src as u64 + 1) * 8
    }

    /// Byte offset of the CSR `perm` array (`csr_nnz` u32).
    pub fn csr_perm_off(&self) -> u64 {
        self.csr_indices_off() + self.csr_nnz as u64 * 4
    }

    /// The exact file size the header implies.
    pub fn expected_len(&self) -> u128 {
        self.csr_perm_off() as u128 + self.csr_nnz as u128 * 4
    }
}

/// Parse and validate one adjacency shard's header against the expected
/// stamp and type-level dimensions; the payload stays untouched.
pub(crate) fn read_adj_header(
    f: &mut File,
    path: &Path,
    stamp: AdjStamp,
    n_src: usize,
    n_dst: usize,
    num_edges: usize,
) -> Result<AdjLayout> {
    let file_len = f.metadata()?.len();
    if file_len < ADJ_HEADER_BYTES {
        return Err(bad(path, "too short for an adjacency shard"));
    }
    let mut head = [0u8; ADJ_HEADER_BYTES as usize];
    f.read_exact(&mut head)?;
    if &head[..8] != ADJ_MAGIC {
        return Err(bad(path, "bad adjacency magic"));
    }
    let word = |i: usize| u64::from_le_bytes(head[8 + i * 8..16 + i * 8].try_into().unwrap());
    let layout = AdjLayout {
        stamp: AdjStamp { et_index: word(0), partition: word(1) },
        n_src: word(2) as usize,
        n_dst: word(3) as usize,
        csc_nnz: word(4) as usize,
        csr_nnz: word(5) as usize,
        payload_hash: word(6),
        file_len,
    };
    if layout.stamp != stamp {
        return Err(bad(
            path,
            &format!(
                "shard is stamped (edge type {}, partition {}), bundle slot expects \
                 (edge type {}, partition {})",
                layout.stamp.et_index, layout.stamp.partition, stamp.et_index, stamp.partition
            ),
        ));
    }
    if layout.n_src != n_src || layout.n_dst != n_dst {
        return Err(bad(
            path,
            &format!(
                "shard is over {}x{} nodes, manifest says {n_src}x{n_dst}",
                layout.n_src, layout.n_dst
            ),
        ));
    }
    if layout.csc_nnz > num_edges || layout.csr_nnz > num_edges {
        return Err(bad(path, "shard claims more edges than the edge type has"));
    }
    if layout.expected_len() != file_len as u128 {
        return Err(bad(
            path,
            &format!("expected {} bytes, file holds {file_len}", layout.expected_len()),
        ));
    }
    Ok(layout)
}

/// Write one partition's adjacency shard of one edge type: the in-edge
/// CSC (keyed by type-global dst id) and the out-edge CSR (keyed by
/// type-global src id), both carrying type-global edge ids in `perm`.
///
/// Layout after the magic: the identity stamp `(et_index, partition)`,
/// then `n_src, n_dst, csc_nnz, csr_nnz` and the FNV-1a hash of the
/// payload (all u64 LE), then `csc.indptr` (`n_dst + 1` u64),
/// `csc.indices`/`csc.perm` (`csc_nnz` u32 each), `csr.indptr`
/// (`n_src + 1` u64), `csr.indices`/`csr.perm` (`csr_nnz` u32 each).
pub fn write_adjacency_shard(
    path: &Path,
    stamp: AdjStamp,
    n_src: usize,
    n_dst: usize,
    csc: &Compressed,
    csr: &Compressed,
) -> Result<()> {
    let mut buf = Vec::new();
    for compressed in [csc, csr] {
        for &p in &compressed.indptr {
            buf.extend_from_slice(&(p as u64).to_le_bytes());
        }
        for &v in &compressed.indices {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &compressed.perm {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    let mut hash = Fnv1a::new();
    hash.update(&buf);

    let mut f = File::create(path)?;
    f.write_all(ADJ_MAGIC)?;
    for v in [
        stamp.et_index,
        stamp.partition,
        n_src as u64,
        n_dst as u64,
        csc.num_edges() as u64,
        csr.num_edges() as u64,
        hash.finish(),
    ] {
        f.write_all(&v.to_le_bytes())?;
    }
    f.write_all(&buf)?;
    f.sync_all()?;
    Ok(())
}

/// Read and fully validate one adjacency shard written by
/// [`write_adjacency_shard`]. `stamp` is the bundle slot being loaded;
/// `n_src` / `n_dst` / `num_edges` are the expected type-level
/// dimensions from the bundle manifest. Any stamp or dimension
/// mismatch, checksum drift, out-of-bounds index, non-monotone
/// `indptr`, or size drift is an [`Error`].
pub fn read_adjacency_shard(
    path: &Path,
    stamp: AdjStamp,
    n_src: usize,
    n_dst: usize,
    num_edges: usize,
) -> Result<(Compressed, Compressed)> {
    let mut f = File::open(path)?;
    let layout = read_adj_header(&mut f, path, stamp, n_src, n_dst, num_edges)?;
    let mut payload = vec![0u8; (layout.file_len - ADJ_HEADER_BYTES) as usize];
    f.read_exact(&mut payload)?;
    let mut hash = Fnv1a::new();
    hash.update(&payload);
    if hash.finish() != layout.payload_hash {
        return Err(bad(path, "payload checksum mismatch"));
    }
    let (csc_nnz, csr_nnz) = (layout.csc_nnz, layout.csr_nnz);
    let mut off = 0usize;
    let csc_indptr = take_u64s(&payload, &mut off, n_dst + 1);
    let csc_indices = take_u32s(&payload, &mut off, csc_nnz);
    let csc_perm = take_u32s(&payload, &mut off, csc_nnz);
    let csr_indptr = take_u64s(&payload, &mut off, n_src + 1);
    let csr_indices = take_u32s(&payload, &mut off, csr_nnz);
    let csr_perm = take_u32s(&payload, &mut off, csr_nnz);
    debug_assert_eq!(off, payload.len());

    let csc = Compressed { indptr: csc_indptr, indices: csc_indices, perm: csc_perm };
    let csr = Compressed { indptr: csr_indptr, indices: csr_indices, perm: csr_perm };
    validate_compressed(path, "csc", &csc, csc_nnz, n_src, num_edges)?;
    validate_compressed(path, "csr", &csr, csr_nnz, n_dst, num_edges)?;
    Ok((csc, csr))
}

fn take_u64s(payload: &[u8], off: &mut usize, count: usize) -> Vec<usize> {
    let out = payload[*off..*off + count * 8]
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
        .collect();
    *off += count * 8;
    out
}

fn take_u32s(payload: &[u8], off: &mut usize, count: usize) -> Vec<u32> {
    let out = payload[*off..*off + count * 4]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    *off += count * 4;
    out
}

/// Structural validation of one compressed half: monotone `indptr`
/// ending at `nnz`, neighbor ids below `n_other`, edge ids below
/// `num_edges`.
fn validate_compressed(
    path: &Path,
    which: &str,
    c: &Compressed,
    nnz: usize,
    n_other: usize,
    num_edges: usize,
) -> Result<()> {
    if c.indptr.first() != Some(&0) || c.indptr.last() != Some(&nnz) {
        return Err(bad(path, &format!("{which} indptr does not span 0..{nnz}")));
    }
    if c.indptr.windows(2).any(|w| w[0] > w[1]) {
        return Err(bad(path, &format!("{which} indptr is not monotone")));
    }
    if c.indices.iter().any(|&v| v as usize >= n_other) {
        return Err(bad(path, &format!("{which} neighbor id out of range ({n_other} nodes)")));
    }
    if c.perm.iter().any(|&e| e as usize >= num_edges) {
        return Err(bad(path, &format!("{which} edge id out of range ({num_edges} edges)")));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("pyg2_persist_io");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    const STAMP: AdjStamp = AdjStamp { et_index: 0, partition: 0 };

    #[test]
    fn u32_and_i64_arrays_roundtrip() {
        let p = tmp("a.u32");
        write_u32_array(&p, &[3, 0, 7, u32::MAX]).unwrap();
        assert_eq!(read_u32_array(&p).unwrap(), vec![3, 0, 7, u32::MAX]);
        let q = tmp("a.i64");
        write_i64_array(&q, &[-5, 0, i64::MAX]).unwrap();
        assert_eq!(read_i64_array(&q).unwrap(), vec![-5, 0, i64::MAX]);
        // Empty arrays are valid.
        write_u32_array(&p, &[]).unwrap();
        assert!(read_u32_array(&p).unwrap().is_empty());
    }

    #[test]
    fn size_drift_and_bad_magic_rejected() {
        let p = tmp("drift.u32");
        write_u32_array(&p, &[1, 2, 3]).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // Truncated.
        std::fs::write(&p, &bytes[..bytes.len() - 1]).unwrap();
        assert!(read_u32_array(&p).is_err());
        // Extended.
        let mut longer = bytes.clone();
        longer.push(0);
        std::fs::write(&p, &longer).unwrap();
        assert!(read_u32_array(&p).is_err());
        // Wrong magic (an i64 file read as u32).
        write_i64_array(&p, &[1]).unwrap();
        assert!(read_u32_array(&p).is_err());
    }

    fn toy_shard() -> (Compressed, Compressed) {
        // 3 dst nodes, 2 src nodes, 3 edges.
        let csc = Compressed {
            indptr: vec![0, 1, 1, 3],
            indices: vec![0, 1, 0],
            perm: vec![2, 0, 1],
        };
        let csr = Compressed { indptr: vec![0, 2, 3], indices: vec![0, 2, 2], perm: vec![2, 1, 0] };
        (csc, csr)
    }

    #[test]
    fn adjacency_shard_roundtrips() {
        let (csc, csr) = toy_shard();
        let p = tmp("shard.pyga");
        write_adjacency_shard(&p, STAMP, 2, 3, &csc, &csr).unwrap();
        let (rc, rr) = read_adjacency_shard(&p, STAMP, 2, 3, 3).unwrap();
        assert_eq!(rc, csc);
        assert_eq!(rr, csr);
    }

    #[test]
    fn adjacency_validation_catches_corruption() {
        let (csc, csr) = toy_shard();
        let p = tmp("shard_bad.pyga");
        write_adjacency_shard(&p, STAMP, 2, 3, &csc, &csr).unwrap();
        let bytes = std::fs::read(&p).unwrap();

        // Wrong expected dims.
        assert!(read_adjacency_shard(&p, STAMP, 2, 4, 3).is_err());
        assert!(read_adjacency_shard(&p, STAMP, 3, 3, 3).is_err());
        // Fewer edges than the perm entries claim.
        assert!(read_adjacency_shard(&p, STAMP, 2, 3, 2).is_err());
        // A re-pointed shard: the stamp no longer matches the slot.
        assert!(read_adjacency_shard(&p, AdjStamp { et_index: 0, partition: 1 }, 2, 3, 3).is_err());
        assert!(read_adjacency_shard(&p, AdjStamp { et_index: 1, partition: 0 }, 2, 3, 3).is_err());
        // Truncation.
        std::fs::write(&p, &bytes[..bytes.len() - 2]).unwrap();
        assert!(read_adjacency_shard(&p, STAMP, 2, 3, 3).is_err());
        // Bit-flip every byte position in turn: the header is stamp-,
        // dimension- and size-checked, and the payload is checksummed,
        // so every flip must be rejected — and must never panic.
        for i in 0..bytes.len() {
            let mut evil = bytes.clone();
            evil[i] ^= 0x80;
            std::fs::write(&p, &evil).unwrap();
            assert!(
                read_adjacency_shard(&p, STAMP, 2, 3, 3).is_err(),
                "byte {i} flipped must not parse"
            );
        }
        // A neighbor id pushed out of range is rejected (re-hash the
        // payload so only the structural validator can catch it).
        let mut evil = bytes.clone();
        let idx_off = ADJ_HEADER_BYTES as usize + 4 * 8; // csc.indices after 4 indptr u64s
        evil[idx_off..idx_off + 4].copy_from_slice(&99u32.to_le_bytes());
        let mut hash = Fnv1a::new();
        hash.update(&evil[ADJ_HEADER_BYTES as usize..]);
        evil[56..64].copy_from_slice(&hash.finish().to_le_bytes());
        std::fs::write(&p, &evil).unwrap();
        assert!(read_adjacency_shard(&p, STAMP, 2, 3, 3).is_err());
    }

    #[test]
    fn page_source_backends_read_identically() {
        let p = tmp("src.u32");
        write_u32_array(&p, &(0..100u32).collect::<Vec<_>>()).unwrap();
        let expect = std::fs::read(&p).unwrap();
        let mut backends = vec![IoBackend::Pread];
        if cfg!(unix) {
            backends.push(IoBackend::Mmap);
        }
        for backend in backends {
            let src = page_source(File::open(&p).unwrap(), p.clone(), backend).unwrap();
            assert_eq!(src.len(), expect.len() as u64, "{backend}");
            assert!(!src.is_empty());
            let mut buf = vec![0u8; 40];
            src.read_at(16, &mut buf).unwrap();
            assert_eq!(&buf[..], &expect[16..56], "{backend}");
            // Batched segments land exactly like single reads.
            let mut a = [0u8; 8];
            let mut b = [0u8; 12];
            let mut segs = [
                IoSeg { offset: 0, buf: &mut a },
                IoSeg { offset: 100, buf: &mut b },
            ];
            src.read_batch(&mut segs).unwrap();
            assert_eq!(&a[..], &expect[..8], "{backend}");
            assert_eq!(&b[..], &expect[100..112], "{backend}");
            // Reads past EOF error on every backend, never fault.
            let mut big = vec![0u8; expect.len() + 1];
            assert!(src.read_at(0, &mut big).is_err(), "{backend}");
            assert!(src.read_at(src.len() - 1, &mut [0u8; 2]).is_err(), "{backend}");
        }
        assert_eq!(IoBackend::parse("pread").unwrap(), IoBackend::Pread);
        assert_eq!(IoBackend::parse("mmap").unwrap(), IoBackend::Mmap);
        assert!(IoBackend::parse("uring").is_err());
        assert_eq!(IoBackend::default(), IoBackend::Pread);
    }

    #[test]
    fn open_i64_array_validates_without_reading_payload() {
        let p = tmp("paged.i64");
        write_i64_array(&p, &[1, 2, 3]).unwrap();
        let (_, count) = open_i64_array(&p).unwrap();
        assert_eq!(count, 3);
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 1]).unwrap();
        assert!(open_i64_array(&p).is_err(), "truncated time file rejected at open");
        write_u32_array(&p, &[1, 2, 3]).unwrap();
        assert!(open_i64_array(&p).is_err(), "wrong-width file rejected at open");
    }
}
