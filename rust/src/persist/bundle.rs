//! Partition bundles: the on-disk layout of a partitioned graph.
//!
//! A bundle is a directory holding everything a rank needs to join a
//! distributed run without reloading or re-partitioning the original
//! dataset — feature rows stay on disk (demand-paged at mount time with
//! O(batch) memory), adjacency travels as compact per-partition binary
//! shards:
//!
//! ```text
//! bundle/
//!   manifest.json             format, num_parts, node/edge type metadata
//!   nodes/<nt>.assign         per-type ownership vector (u32 per node)
//!   nodes/<nt>.y              optional labels (i64 per node)
//!   nodes/<nt>.time           optional node timestamps
//!   features/<nt>.p<p>.pygf   feature shard of (node_type, partition)
//!   adj/<et>.p<p>.pyga        CSC/CSR adjacency shard of (edge_type, partition)
//!   adj/<et>.time             optional edge timestamps (global edge-id order)
//! ```
//!
//! Feature shards reuse the positioned-I/O `.pygf` format of
//! [`crate::storage::FileFeatureStore`]: shard `(nt, p)` holds the rows
//! of the nodes partition `p` owns, in ascending type-global id order —
//! exactly the layout [`crate::dist::PartitionedFeatureStore`]'s
//! in-memory shards use, so a mounted pipeline is seed-for-seed
//! identical to the in-memory one. Adjacency shards serialize the same
//! per-partition CSC/CSR halves [`crate::dist::EdgeShards`] builds
//! (in-edges with the destination's owner, out-edges with the source's,
//! type-global ids throughout). Homogeneous graphs are the single-type
//! special case: one `_default` node type, one edge type.
//!
//! Every file is validated on open — magic, exact sizes, id bounds, path
//! safety — so corrupt bundles fail with [`Error`]s, never panics.

use super::io;
use crate::dist::{PartitionRouter, PartitionedGraphStore, TypedRouter};
use crate::error::{Error, Result};
use crate::graph::{EdgeType, Graph, HeteroGraph};
use crate::partition::{Partitioning, TypedPartitioning};
use crate::storage::{FeatureKey, FileFeatureWriter, DEFAULT_ATTR, DEFAULT_GROUP};
use crate::tensor::Tensor;
use crate::util::json::{self, Json};
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::Arc;

const FORMAT: &str = "pyg2-partition-bundle";
const VERSION: f64 = 1.0;

/// Hidden group stamped into every feature shard: a `[1, 2]` tensor
/// holding `(node_type_index, partition)`. The mount verifies it, so a
/// tampered manifest cannot silently point a shard slot at another
/// (shape-compatible) shard file. Double-underscore attrs are filtered
/// out of [`crate::persist::PagedFeatureStore`]'s key space, so the
/// stamp is invisible to the pipeline.
pub(crate) const STAMP_ATTR: &str = "__bundle_shard";

/// Manifest entry of one node type.
#[derive(Clone, Debug)]
pub struct NodeTypeMeta {
    pub name: String,
    pub num_nodes: usize,
    assignment: String,
    labels: Option<String>,
    time: Option<String>,
    /// One feature shard path per partition.
    features: Vec<String>,
}

/// Manifest entry of one edge type.
#[derive(Clone, Debug)]
pub struct EdgeTypeMeta {
    pub ty: EdgeType,
    pub num_edges: usize,
    time: Option<String>,
    /// One adjacency shard path per partition.
    shards: Vec<String>,
}

/// Parsed and validated `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub num_parts: usize,
    pub node_types: Vec<NodeTypeMeta>,
    pub edge_types: Vec<EdgeTypeMeta>,
}

/// An opened partition bundle: the manifest plus the directory the
/// relative paths resolve against. Opening only reads the manifest —
/// shard files are opened lazily by the mount constructors.
pub struct Bundle {
    dir: PathBuf,
    manifest: Manifest,
}

/// Reject absolute paths and `..` components: a manifest must not be
/// able to read outside its bundle directory.
fn safe_path(p: &str) -> Result<&str> {
    let path = Path::new(p);
    let escapes = path.is_absolute()
        || path
            .components()
            .any(|c| !matches!(c, std::path::Component::Normal(_)));
    if p.is_empty() || escapes {
        return Err(Error::Storage(format!("manifest path {p:?} escapes the bundle")));
    }
    Ok(p)
}

fn req_str<'a>(v: &'a Json, field: &str) -> Result<&'a str> {
    v.get(field)
        .and_then(|f| f.as_str())
        .ok_or_else(|| Error::Storage(format!("manifest missing string field {field}")))
}

/// Required size field (shared strict validation: [`json::uint_field`]).
fn req_usize(v: &Json, field: &str) -> Result<usize> {
    json::uint_field(v, field)
        .map(|n| n as usize)
        .map_err(|e| Error::Storage(format!("manifest: {e}")))
}

fn opt_path(v: &Json, field: &str) -> Result<Option<String>> {
    match v.get(field) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Str(s)) => Ok(Some(safe_path(s)?.to_string())),
        Some(other) => Err(Error::Storage(format!(
            "manifest field {field} is not a path: {other:?}"
        ))),
    }
}

/// Strict-schema check: a manifest object carrying a key outside its
/// schema is treated as corrupt (a bit flip in a key name must not
/// silently drop the field it renamed).
fn check_keys(v: &Json, allowed: &[&str], what: &str) -> Result<()> {
    let obj = v
        .as_obj()
        .ok_or_else(|| Error::Storage(format!("manifest {what} entry is not an object")))?;
    for k in obj.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(Error::Storage(format!("unknown manifest {what} field {k}")));
        }
    }
    Ok(())
}

fn path_list(v: &Json, field: &str, expect: usize) -> Result<Vec<String>> {
    let arr = v
        .get(field)
        .and_then(|f| f.as_arr())
        .ok_or_else(|| Error::Storage(format!("manifest missing path list {field}")))?;
    if arr.len() != expect {
        return Err(Error::Storage(format!(
            "manifest lists {} {field} shards, bundle has {expect} partitions",
            arr.len()
        )));
    }
    arr.iter()
        .map(|p| {
            p.as_str()
                .ok_or_else(|| Error::Storage(format!("non-string path in {field}")))
                .and_then(|s| safe_path(s).map(str::to_string))
        })
        .collect()
}

impl Bundle {
    /// Open a bundle directory: parse and validate its manifest.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Storage(format!("{}: cannot read manifest.json: {e}", dir.display()))
        })?;
        let doc = json::parse(&text)
            .map_err(|e| Error::Storage(format!("{}: bad manifest json: {e}", dir.display())))?;
        check_keys(
            &doc,
            &["format", "version", "num_parts", "node_types", "edge_types"],
            "top-level",
        )?;
        if req_str(&doc, "format")? != FORMAT {
            return Err(Error::Storage(format!("{} is not a partition bundle", dir.display())));
        }
        if doc.get("version").and_then(|v| v.as_f64()) != Some(VERSION) {
            return Err(Error::Storage("unsupported bundle version".into()));
        }
        let num_parts = req_usize(&doc, "num_parts")?;
        if num_parts == 0 {
            return Err(Error::Storage("bundle needs at least one partition".into()));
        }

        let mut node_types = Vec::new();
        let mut names = BTreeSet::new();
        for nt in doc
            .get("node_types")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| Error::Storage("manifest missing node_types".into()))?
        {
            check_keys(
                nt,
                &["name", "num_nodes", "assignment", "labels", "time", "features"],
                "node-type",
            )?;
            let name = req_str(nt, "name")?.to_string();
            if !names.insert(name.clone()) {
                return Err(Error::Storage(format!("duplicate node type {name}")));
            }
            node_types.push(NodeTypeMeta {
                num_nodes: req_usize(nt, "num_nodes")?,
                assignment: safe_path(req_str(nt, "assignment")?)?.to_string(),
                labels: opt_path(nt, "labels")?,
                time: opt_path(nt, "time")?,
                features: path_list(nt, "features", num_parts)?,
                name,
            });
        }
        if node_types.is_empty() {
            return Err(Error::Storage("bundle has no node types".into()));
        }

        let mut edge_types = Vec::new();
        let mut edge_keys = BTreeSet::new();
        for et in doc
            .get("edge_types")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| Error::Storage("manifest missing edge_types".into()))?
        {
            check_keys(
                et,
                &["src", "rel", "dst", "num_edges", "time", "shards"],
                "edge-type",
            )?;
            let ty = EdgeType::new(req_str(et, "src")?, req_str(et, "rel")?, req_str(et, "dst")?);
            for endpoint in [&ty.src, &ty.dst] {
                if !names.contains(endpoint) {
                    return Err(Error::Storage(format!(
                        "edge type {} references unknown node type {endpoint}",
                        ty.key()
                    )));
                }
            }
            if !edge_keys.insert(ty.key()) {
                return Err(Error::Storage(format!("duplicate edge type {}", ty.key())));
            }
            edge_types.push(EdgeTypeMeta {
                num_edges: req_usize(et, "num_edges")?,
                time: opt_path(et, "time")?,
                shards: path_list(et, "shards", num_parts)?,
                ty,
            });
        }

        Ok(Self { dir, manifest: Manifest { num_parts, node_types, edge_types } })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn num_parts(&self) -> usize {
        self.manifest.num_parts
    }

    /// Whether this is a typed (heterogeneous) bundle rather than the
    /// single-`_default`-type homogeneous special case.
    pub fn is_typed(&self) -> bool {
        self.manifest.node_types.len() != 1 || self.manifest.node_types[0].name != DEFAULT_GROUP
    }

    pub fn node_type(&self, name: &str) -> Result<&NodeTypeMeta> {
        self.manifest
            .node_types
            .iter()
            .find(|nt| nt.name == name)
            .ok_or_else(|| Error::Storage(format!("bundle has no node type {name}")))
    }

    pub fn edge_type(&self, ty: &EdgeType) -> Result<&EdgeTypeMeta> {
        self.manifest
            .edge_types
            .iter()
            .find(|et| &et.ty == ty)
            .ok_or_else(|| Error::Storage(format!("bundle has no edge type {}", ty.key())))
    }

    /// The ownership vector of one node type, validated against the
    /// manifest's node count and partition count.
    pub fn load_assignment(&self, node_type: &str) -> Result<Vec<u32>> {
        let meta = self.node_type(node_type)?;
        let assignment = io::read_u32_array(&self.dir.join(&meta.assignment))?;
        if assignment.len() != meta.num_nodes {
            return Err(Error::Storage(format!(
                "{node_type} assignment covers {} nodes, manifest says {}",
                assignment.len(),
                meta.num_nodes
            )));
        }
        if let Some(&bad) = assignment.iter().find(|&&p| p as usize >= self.manifest.num_parts) {
            return Err(Error::Storage(format!(
                "{node_type} assignment references partition {bad} of {}",
                self.manifest.num_parts
            )));
        }
        Ok(assignment)
    }

    /// Labels of one node type, if the bundle carries them.
    pub fn load_labels(&self, node_type: &str) -> Result<Option<Vec<i64>>> {
        let meta = self.node_type(node_type)?;
        self.load_aligned_i64(meta.labels.as_deref(), meta.num_nodes, "labels")
    }

    /// Node timestamps of one node type, if present.
    pub fn load_node_time(&self, node_type: &str) -> Result<Option<Vec<i64>>> {
        let meta = self.node_type(node_type)?;
        self.load_aligned_i64(meta.time.as_deref(), meta.num_nodes, "node time")
    }

    /// Edge timestamps of one edge type (global edge-id order), if
    /// present.
    pub fn load_edge_time(&self, ty: &EdgeType) -> Result<Option<Vec<i64>>> {
        let meta = self.edge_type(ty)?;
        self.load_aligned_i64(meta.time.as_deref(), meta.num_edges, "edge time")
    }

    fn load_aligned_i64(
        &self,
        path: Option<&str>,
        expect: usize,
        what: &str,
    ) -> Result<Option<Vec<i64>>> {
        let Some(path) = path else { return Ok(None) };
        let data = io::read_i64_array(&self.dir.join(path))?;
        if data.len() != expect {
            return Err(Error::Storage(format!(
                "{what} file holds {} entries, expected {expect}",
                data.len()
            )));
        }
        Ok(Some(data))
    }

    /// Position of one edge type in the manifest — the `et_index` half
    /// of the adjacency shards' identity stamp
    /// ([`crate::persist::io::AdjStamp`]).
    pub fn edge_type_index(&self, ty: &EdgeType) -> Result<usize> {
        self.manifest
            .edge_types
            .iter()
            .position(|et| &et.ty == ty)
            .ok_or_else(|| Error::Storage(format!("bundle has no edge type {}", ty.key())))
    }

    /// Load and validate every partition's adjacency shard of one edge
    /// type: `(csc, csr)` per partition, in partition order.
    pub fn load_adjacency(
        &self,
        ty: &EdgeType,
    ) -> Result<Vec<(crate::graph::Compressed, crate::graph::Compressed)>> {
        let ei = self.edge_type_index(ty)?;
        let meta = self.edge_type(ty)?;
        let n_src = self.node_type(&ty.src)?.num_nodes;
        let n_dst = self.node_type(&ty.dst)?.num_nodes;
        meta.shards
            .iter()
            .enumerate()
            .map(|(p, rel)| {
                io::read_adjacency_shard(
                    &self.dir.join(rel),
                    io::AdjStamp { et_index: ei as u64, partition: p as u64 },
                    n_src,
                    n_dst,
                    meta.num_edges,
                )
            })
            .collect()
    }

    /// Path of the feature shard of `(node_type, partition)`.
    pub fn feature_shard_path(&self, node_type: &str, part: usize) -> Result<PathBuf> {
        let meta = self.node_type(node_type)?;
        let rel = meta.features.get(part).ok_or_else(|| {
            Error::Storage(format!("partition {part} out of {}", self.manifest.num_parts))
        })?;
        Ok(self.dir.join(rel))
    }

    /// Path of the adjacency shard of `(edge_type, partition)` — the
    /// file a demand-paged mount opens for positioned reads.
    pub fn adjacency_shard_path(&self, ty: &EdgeType, part: usize) -> Result<PathBuf> {
        let meta = self.edge_type(ty)?;
        let rel = meta.shards.get(part).ok_or_else(|| {
            Error::Storage(format!("partition {part} out of {}", self.manifest.num_parts))
        })?;
        Ok(self.dir.join(rel))
    }

    /// Path of one edge type's timestamp file, if the bundle carries
    /// timestamps for it.
    pub fn edge_time_path(&self, ty: &EdgeType) -> Result<Option<PathBuf>> {
        Ok(self.edge_type(ty)?.time.as_deref().map(|rel| self.dir.join(rel)))
    }
}

/// File-name-safe rendering of a type name.
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '-' | '_') { c } else { '_' })
        .collect()
}

/// Everything the writer needs about one node type.
struct NodeSpec<'a> {
    name: &'a str,
    x: &'a Tensor,
    y: Option<&'a [i64]>,
    time: Option<&'a [i64]>,
    assignment: &'a [u32],
}

/// Write a homogeneous graph as a partition bundle (the single-type
/// special case: node type `_default`, the default edge type). Returns
/// the re-opened bundle so callers can mount what was just written.
pub fn write_bundle(
    dir: impl AsRef<Path>,
    g: &Graph,
    partitioning: &Partitioning,
) -> Result<Bundle> {
    if partitioning.assignment.len() != g.num_nodes() {
        return Err(Error::Storage(format!(
            "partitioning covers {} nodes, graph has {}",
            partitioning.assignment.len(),
            g.num_nodes()
        )));
    }
    let router = Arc::new(PartitionRouter::new(partitioning, 0)?);
    let gs = PartitionedGraphStore::from_graph(g, router)?;
    let specs = [NodeSpec {
        name: DEFAULT_GROUP,
        x: &g.x,
        y: g.y.as_deref(),
        time: g.node_time.as_deref(),
        assignment: &partitioning.assignment,
    }];
    write_impl(dir.as_ref(), partitioning.num_parts, &specs, &gs)
}

/// Write a heterogeneous graph as a typed partition bundle: feature
/// shards keyed `(node_type, partition)`, adjacency shards
/// `(edge_type, partition)`, per-type ownership vectors.
pub fn write_bundle_hetero(
    dir: impl AsRef<Path>,
    g: &HeteroGraph,
    partitioning: &TypedPartitioning,
) -> Result<Bundle> {
    let router = TypedRouter::new(partitioning, 0)?;
    let gs = PartitionedGraphStore::from_hetero(g, router)?;
    let mut specs = Vec::new();
    for nt in g.node_types() {
        let store = g.node_store(nt)?;
        specs.push(NodeSpec {
            name: nt,
            x: &store.x,
            y: store.y.as_deref(),
            time: store.time.as_deref(),
            assignment: &partitioning.partitioning(nt)?.assignment,
        });
    }
    write_impl(dir.as_ref(), partitioning.num_parts, &specs, &gs)
}

fn write_impl(
    dir: &Path,
    num_parts: usize,
    specs: &[NodeSpec<'_>],
    gs: &PartitionedGraphStore,
) -> Result<Bundle> {
    // Re-writing over an existing bundle must not leave stale shards
    // from a previous (e.g. wider) partitioning mixed into the
    // directory. Only directories that actually hold a bundle (a
    // manifest is present) are cleared.
    if dir.join("manifest.json").exists() {
        for sub in ["nodes", "features", "adj"] {
            let _ = std::fs::remove_dir_all(dir.join(sub));
        }
        std::fs::remove_file(dir.join("manifest.json"))?;
    }
    for sub in ["nodes", "features", "adj"] {
        std::fs::create_dir_all(dir.join(sub))?;
    }

    let mut node_metas = Vec::new();
    for (ti, spec) in specs.iter().enumerate() {
        // Index-prefixed stems keep files distinct even when two type
        // names sanitize to the same string.
        let stem = format!("{ti}_{}", sanitize(spec.name));
        let assign_rel = format!("nodes/{stem}.assign");
        io::write_u32_array(&dir.join(&assign_rel), spec.assignment)?;
        let labels_rel = match spec.y {
            Some(y) => {
                let rel = format!("nodes/{stem}.y");
                io::write_i64_array(&dir.join(&rel), y)?;
                Some(rel)
            }
            None => None,
        };
        let time_rel = match spec.time {
            Some(t) => {
                let rel = format!("nodes/{stem}.time");
                io::write_i64_array(&dir.join(&rel), t)?;
                Some(rel)
            }
            None => None,
        };
        // One feature shard per partition: the owned rows, ascending by
        // type-global id — the exact layout the in-memory partitioned
        // store shards into, so a mount reproduces it bit for bit.
        // (Single bucketing pass; the assignment was validated against
        // num_parts when the graph store's routers were built.)
        let mut owned: Vec<Vec<usize>> = vec![Vec::new(); num_parts];
        for (v, &a) in spec.assignment.iter().enumerate() {
            owned[a as usize].push(v);
        }
        let mut feature_rels = Vec::with_capacity(num_parts);
        for (p, idx) in owned.iter().enumerate() {
            let rel = format!("features/{stem}.p{p}.pygf");
            let mut w = FileFeatureWriter::new(dir.join(&rel));
            w.put(FeatureKey::new(spec.name, DEFAULT_ATTR), spec.x.gather_rows(idx)?);
            // Shard identity stamp (see [`STAMP_ATTR`]): which
            // (node_type, partition) this file is, verified at mount.
            w.put(
                FeatureKey::new(spec.name, STAMP_ATTR),
                Tensor::new(vec![1, 2], vec![ti as f32, p as f32])?,
            );
            w.finish()?;
            feature_rels.push(rel);
        }
        node_metas.push(Json::obj(vec![
            ("name", Json::str(spec.name)),
            ("num_nodes", Json::num(spec.assignment.len() as f64)),
            ("assignment", Json::str(assign_rel)),
            ("labels", labels_rel.map(Json::str).unwrap_or(Json::Null)),
            ("time", time_rel.map(Json::str).unwrap_or(Json::Null)),
            (
                "features",
                Json::Arr(feature_rels.into_iter().map(Json::str).collect()),
            ),
        ]));
    }

    let mut edge_metas = Vec::new();
    for (ei, ty) in crate::storage::GraphStore::edge_types(gs).iter().enumerate() {
        let es = gs.edges_of(ty)?;
        let (n_src, n_dst) = es.dims();
        let stem = format!(
            "{ei}_{}__{}__{}",
            sanitize(&ty.src),
            sanitize(&ty.rel),
            sanitize(&ty.dst)
        );
        let mut shard_rels = Vec::with_capacity(num_parts);
        for (p, (csc, csr)) in es.shard_views()?.into_iter().enumerate() {
            let rel = format!("adj/{stem}.p{p}.pyga");
            io::write_adjacency_shard(
                &dir.join(&rel),
                io::AdjStamp { et_index: ei as u64, partition: p as u64 },
                n_src,
                n_dst,
                csc,
                csr,
            )?;
            shard_rels.push(rel);
        }
        let time_rel = match es.edge_time_slice() {
            Some(t) => {
                let rel = format!("adj/{stem}.time");
                io::write_i64_array(&dir.join(&rel), t)?;
                Some(rel)
            }
            None => None,
        };
        edge_metas.push(Json::obj(vec![
            ("src", Json::str(ty.src.clone())),
            ("rel", Json::str(ty.rel.clone())),
            ("dst", Json::str(ty.dst.clone())),
            ("num_edges", Json::num(es.num_edges() as f64)),
            ("time", time_rel.map(Json::str).unwrap_or(Json::Null)),
            ("shards", Json::Arr(shard_rels.into_iter().map(Json::str).collect())),
        ]));
    }

    let manifest = Json::obj(vec![
        ("format", Json::str(FORMAT)),
        ("version", Json::num(VERSION)),
        ("num_parts", Json::num(num_parts as f64)),
        ("node_types", Json::Arr(node_metas)),
        ("edge_types", Json::Arr(edge_metas)),
    ]);
    std::fs::write(dir.join("manifest.json"), manifest.to_string())?;
    Bundle::open(dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::sbm::{self, SbmConfig};
    use crate::partition::ldg_partition;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("pyg2_bundle_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn toy_bundle(name: &str) -> (Graph, Partitioning, Bundle) {
        let g = sbm::generate(&SbmConfig { num_nodes: 120, seed: 3, ..Default::default() })
            .unwrap();
        let p = ldg_partition(&g.edge_index, 3, 1.1).unwrap();
        let bundle = write_bundle(tmp(name), &g, &p).unwrap();
        (g, p, bundle)
    }

    #[test]
    fn manifest_roundtrips_and_validates() {
        let (g, p, bundle) = toy_bundle("roundtrip");
        assert_eq!(bundle.num_parts(), 3);
        assert!(!bundle.is_typed());
        let m = bundle.manifest();
        assert_eq!(m.node_types.len(), 1);
        assert_eq!(m.node_types[0].num_nodes, 120);
        assert_eq!(m.edge_types.len(), 1);
        assert_eq!(m.edge_types[0].num_edges, g.num_edges());
        assert_eq!(bundle.load_assignment(DEFAULT_GROUP).unwrap(), p.assignment);
        assert_eq!(bundle.load_labels(DEFAULT_GROUP).unwrap(), g.y);
        assert!(bundle.load_node_time(DEFAULT_GROUP).unwrap().is_none());
        let ty = m.edge_types[0].ty.clone();
        let shards = bundle.load_adjacency(&ty).unwrap();
        assert_eq!(shards.len(), 3);
        let stored: usize = shards.iter().map(|(csc, _)| csc.num_edges()).sum();
        assert_eq!(stored, g.num_edges(), "in-shards tile the edge set");
        assert!(bundle.node_type("ghost").is_err());
        assert!(bundle
            .edge_type(&EdgeType::new("a", "b", "c"))
            .is_err());
        assert!(bundle.feature_shard_path(DEFAULT_GROUP, 0).unwrap().exists());
        assert!(bundle.feature_shard_path(DEFAULT_GROUP, 3).is_err());
    }

    #[test]
    fn unsafe_manifest_paths_rejected() {
        let (_, _, bundle) = toy_bundle("unsafe");
        let path = bundle.dir().join("manifest.json");
        let text = std::fs::read_to_string(&path).unwrap();
        for evil in [
            text.replace("nodes/0__default.assign", "../outside.assign"),
            text.replace("nodes/0__default.assign", "/etc/passwd"),
        ] {
            std::fs::write(&path, evil).unwrap();
            assert!(Bundle::open(bundle.dir()).is_err());
        }
    }

    #[test]
    fn rewriting_a_bundle_clears_stale_shards() {
        let g = sbm::generate(&SbmConfig { num_nodes: 60, seed: 2, ..Default::default() })
            .unwrap();
        let dir = tmp("rewrite");
        let p3 = ldg_partition(&g.edge_index, 3, 1.1).unwrap();
        write_bundle(&dir, &g, &p3).unwrap();
        let stale = dir.join("features/0__default.p2.pygf");
        assert!(stale.exists());
        let p2 = ldg_partition(&g.edge_index, 2, 1.1).unwrap();
        let bundle = write_bundle(&dir, &g, &p2).unwrap();
        assert_eq!(bundle.num_parts(), 2);
        assert!(!stale.exists(), "wider-partitioning shard must be cleared");
    }

    #[test]
    fn mismatched_partitioning_rejected_at_write() {
        let g = sbm::generate(&SbmConfig { num_nodes: 50, seed: 1, ..Default::default() })
            .unwrap();
        let p = Partitioning { assignment: vec![0; 49], num_parts: 1 };
        assert!(write_bundle(tmp("mismatch"), &g, &p).is_err());
    }

    #[test]
    fn concurrent_readonly_mounts_see_identical_bytes() {
        // `pyg2 dist --procs N` has every worker process `Bundle::open`
        // the same directory simultaneously; model that here with
        // threads, each holding its own independent handle. Every
        // mount must decode the same assignment, labels and adjacency
        // with no interference.
        let (g, p, bundle) = toy_bundle("concurrent");
        let dir = bundle.dir().to_path_buf();
        let baseline_adj: Vec<usize> = bundle
            .load_adjacency(&bundle.manifest().edge_types[0].ty.clone())
            .unwrap()
            .iter()
            .map(|(csc, _)| csc.num_edges())
            .collect();
        let joins: Vec<_> = (0..4)
            .map(|_| {
                let dir = dir.clone();
                let assignment = p.assignment.clone();
                let labels = g.y.clone();
                let adj = baseline_adj.clone();
                std::thread::spawn(move || {
                    let b = Bundle::open(&dir).unwrap();
                    assert_eq!(b.num_parts(), 3);
                    assert_eq!(b.load_assignment(DEFAULT_GROUP).unwrap(), assignment);
                    assert_eq!(b.load_labels(DEFAULT_GROUP).unwrap(), labels);
                    let ty = b.manifest().edge_types[0].ty.clone();
                    let got: Vec<usize> = b
                        .load_adjacency(&ty)
                        .unwrap()
                        .iter()
                        .map(|(csc, _)| csc.num_edges())
                        .collect();
                    assert_eq!(got, adj);
                })
            })
            .collect();
        for j in joins {
            j.join().expect("concurrent mount thread panicked");
        }
    }

    #[test]
    fn missing_manifest_and_garbage_rejected() {
        let dir = tmp("absent");
        assert!(Bundle::open(&dir).is_err());
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
        assert!(Bundle::open(&dir).is_err());
        std::fs::write(dir.join("manifest.json"), r#"{"format":"other"}"#).unwrap();
        assert!(Bundle::open(&dir).is_err());
    }
}
