//! Fixed log-bucket histogram math and the one quantile definition.
//!
//! Two consumers share the bucket layout: the atomic [`Histogram`]
//! below (hot-path recording via `fetch_add`, deterministic quantile
//! readout for telemetry snapshots) and the non-atomic
//! [`crate::util::stats::Histogram`] (single-threaded pipeline
//! instrumentation). Likewise [`percentile_sorted`] is the single
//! definition of a percentile over exact samples — `util::stats::Samples`
//! (and through it `TrafficReport::p99_ms` etc.) delegates here, so
//! "p99" means one thing everywhere in the codebase.
//!
//! Bucket layout: values below [`LINEAR_MAX`] get exact unit buckets;
//! above that, each power-of-two octave is split into [`SUB_PER_OCTAVE`]
//! sub-buckets, bounding the relative quantile error at
//! `1 / SUB_PER_OCTAVE` (6.25%). All readouts return the *inclusive
//! upper bound* of the selected bucket, so quantiles are deterministic
//! functions of the recorded counts — no sampling, no interpolation.

use std::sync::atomic::{AtomicU64, Ordering};

/// Sub-buckets per power-of-two octave (16 → ≤6.25% relative error).
const SUB_PER_OCTAVE: u64 = 16;
/// log2 of [`SUB_PER_OCTAVE`].
const SUB_BITS: u32 = 4;
/// Values below this get exact unit-width buckets.
const LINEAR_MAX: u64 = SUB_PER_OCTAVE;
/// Total bucket count: 16 linear + 16 per octave for octaves 4..=63.
pub const NUM_BUCKETS: usize = (LINEAR_MAX + (64 - SUB_BITS as u64) * SUB_PER_OCTAVE) as usize;

/// Bucket index of value `v`. Exact below [`LINEAR_MAX`], log-bucketed
/// with [`SUB_PER_OCTAVE`] sub-buckets per octave above.
pub fn bucket_index(v: u64) -> usize {
    if v < LINEAR_MAX {
        v as usize
    } else {
        let exp = 63 - v.leading_zeros(); // >= SUB_BITS
        let sub = (v >> (exp - SUB_BITS)) & (SUB_PER_OCTAVE - 1);
        (LINEAR_MAX + (exp - SUB_BITS) as u64 * SUB_PER_OCTAVE + sub) as usize
    }
}

/// Inclusive upper bound of bucket `idx` — what quantile readouts report.
pub fn bucket_upper_bound(idx: usize) -> u64 {
    if idx < LINEAR_MAX as usize {
        idx as u64
    } else {
        let oct = (idx - LINEAR_MAX as usize) as u64 / SUB_PER_OCTAVE;
        let sub = (idx - LINEAR_MAX as usize) as u64 % SUB_PER_OCTAVE;
        let width = 1u64 << oct; // sub-bucket width in octave `oct + SUB_BITS`
        let lower = (SUB_PER_OCTAVE + sub) << oct;
        lower + (width - 1)
    }
}

/// Upper bound of the bucket holding the q-quantile (`q` in 0..=1) of
/// the counts, using the nearest-rank convention `ceil(q * n)` (min 1).
/// Returns 0 on an empty histogram. Deterministic given the counts.
pub fn quantile_from_counts(counts: &[u64], q: f64) -> u64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut seen = 0u64;
    for (i, c) in counts.iter().enumerate() {
        seen += c;
        if seen >= target {
            return bucket_upper_bound(i);
        }
    }
    bucket_upper_bound(counts.len() - 1)
}

/// Percentile (`p` in 0..=100) of `xs` via linear interpolation on the
/// sorted copy — the single exact-sample percentile definition
/// (`util::stats::Samples::percentile` delegates here). NaN when empty.
pub fn percentile_sorted(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// A lock-free histogram: fixed log buckets of [`AtomicU64`], recorded
/// into with one relaxed `fetch_add` per sample. ~7.6 KiB per instance.
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        let buckets = (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Self { buckets, sum: AtomicU64::new(0) }
    }

    /// Record one sample (histograms hold raw `u64`s — by convention
    /// microseconds for latency stages, unitless for sizes/depths).
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Consistent-enough copy of the bucket counts (relaxed loads).
    fn counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Quantile readout (`q` in 0..=1): deterministic bucket upper bound.
    pub fn quantile(&self, q: f64) -> u64 {
        quantile_from_counts(&self.counts(), q)
    }

    /// Zero every bucket (bench legs measure per-phase behaviour).
    pub fn reset(&self) {
        for b in self.buckets.iter() {
            b.store(0, Ordering::Relaxed);
        }
        self.sum.store(0, Ordering::Relaxed);
    }

    /// Point-in-time summary used by snapshots and bench reports.
    pub fn snapshot(&self) -> HistSnapshot {
        let counts = self.counts();
        let count: u64 = counts.iter().sum();
        let max = counts
            .iter()
            .rposition(|&c| c > 0)
            .map(bucket_upper_bound)
            .unwrap_or(0);
        HistSnapshot {
            count,
            sum: self.sum.load(Ordering::Relaxed),
            p50: quantile_from_counts(&counts, 0.50),
            p90: quantile_from_counts(&counts, 0.90),
            p95: quantile_from_counts(&counts, 0.95),
            p99: quantile_from_counts(&counts, 0.99),
            max,
        }
    }
}

/// Deterministic summary of a [`Histogram`] at one point in time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub p50: u64,
    pub p90: u64,
    pub p95: u64,
    pub p99: u64,
    pub max: u64,
}

impl HistSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_exact_below_linear_max_and_monotone_above() {
        for v in 0..LINEAR_MAX {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(bucket_upper_bound(bucket_index(v)), v);
        }
        let mut prev = 0;
        for v in [16u64, 17, 31, 32, 1000, 1 << 20, u64::MAX] {
            let idx = bucket_index(v);
            assert!(idx >= prev, "bucket index must be monotone in v");
            prev = idx;
            let ub = bucket_upper_bound(idx);
            assert!(ub >= v, "upper bound {ub} must cover {v}");
            // Relative error of reading the upper bound is <= 1/16.
            assert!(ub - v <= v / SUB_PER_OCTAVE, "bound {ub} too far from {v}");
        }
        assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS - 1);
    }

    #[test]
    fn quantiles_are_pinned_on_known_distributions() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        // Deterministic pins: rank 500 -> value 500 lives in bucket
        // [496, 511]; rank 990 -> 990 in [960, 991]; rank 1000 -> 1000
        // in [992, 1023].
        assert_eq!(h.count(), 1000);
        assert_eq!(h.quantile(0.5), 511);
        assert_eq!(h.quantile(0.99), 991);
        assert_eq!(h.quantile(1.0), 1023);
        let s = h.snapshot();
        assert_eq!((s.p50, s.p99, s.max), (511, 991, 1023));
        assert_eq!(s.sum, 500_500);
        assert!((s.mean() - 500.5).abs() < 1e-9);
        h.reset();
        assert_eq!(h.snapshot(), HistSnapshot::default());
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.snapshot(), HistSnapshot::default());
    }

    #[test]
    fn percentile_sorted_is_pinned() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile_sorted(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile_sorted(&xs, 99.0) - 99.01).abs() < 1e-9);
        assert!((percentile_sorted(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile_sorted(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!(percentile_sorted(&[], 50.0).is_nan());
    }
}
