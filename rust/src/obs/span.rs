//! Stage-span tracing: `obs::span("sample")` times a pipeline stage
//! into the shared histogram `trace.sample_us`.
//!
//! The runtime switch is compile-out-free: when tracing is disabled
//! (the default), [`span`] is a single relaxed atomic load returning a
//! no-op guard — no clock read, no allocation, no registry access — so
//! instrumented hot paths cost nothing measurable. When enabled (the
//! CLI's `--metrics-out`, or a bench leg), the guard stamps
//! `Instant::now()` and its `Drop` records the elapsed microseconds.
//!
//! Stage histograms are shared across threads and instances — that is
//! the point: the per-stage view aggregates every worker's batches.
//! Each thread caches its `Arc<Histogram>` handles in a thread-local
//! map keyed by the `&'static str` stage name, so the registry mutex
//! is touched once per (thread, stage), not per span. Durations are
//! recorded directly into the shared atomic buckets at span end rather
//! than buffered per thread: buffering would be cheaper still, but a
//! snapshot could then miss samples parked in other threads' buffers,
//! and one relaxed `fetch_add` per stage is already far below the cost
//! of the stages being timed.
//!
//! Spans nest freely — each guard times its own interval independently,
//! so a `sample` span inside a `batch` span contributes to both stages.

use super::hist::Histogram;
use super::registry;
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Whether stage-span tracing is on. One relaxed load.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn stage-span tracing on or off at runtime (process-wide).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

thread_local! {
    static STAGE_CACHE: RefCell<HashMap<&'static str, Arc<Histogram>>> =
        RefCell::new(HashMap::new());
}

/// The shared `trace.{stage}_us` histogram, via the thread-local cache.
fn stage_hist(stage: &'static str) -> Arc<Histogram> {
    STAGE_CACHE.with(|c| {
        Arc::clone(
            c.borrow_mut()
                .entry(stage)
                .or_insert_with(|| registry::histogram(&format!("trace.{stage}_us"))),
        )
    })
}

/// Time a pipeline stage until the guard drops. Disabled → no-op guard.
pub fn span(stage: &'static str) -> Span {
    if !enabled() {
        return Span(None);
    }
    Span(Some((Instant::now(), stage_hist(stage))))
}

/// Guard returned by [`span`]; records elapsed microseconds on drop.
pub struct Span(Option<(Instant, Arc<Histogram>)>);

impl Span {
    /// Whether this guard is actually timing (tracing was enabled).
    pub fn is_live(&self) -> bool {
        self.0.is_some()
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((start, hist)) = self.0.take() {
            hist.record(start.elapsed().as_micros() as u64);
        }
    }
}

/// Record an already-measured stage duration (for stages whose start
/// predates the current scope, e.g. queue wait stamped at admission).
pub fn record_stage(stage: &'static str, micros: u64) {
    if enabled() {
        stage_hist(stage).record(micros);
    }
}

/// Snapshots of every `trace.*` stage histogram with at least one
/// sample, as `(stage, snapshot)` with the `trace.`/`_us` trimmed —
/// what the benches fold into their per-stage breakdown metrics.
pub fn stage_report() -> Vec<(String, super::hist::HistSnapshot)> {
    let (_, _, hists) = registry::read_all();
    hists
        .into_iter()
        .filter(|(name, s)| name.starts_with("trace.") && s.count > 0)
        .map(|(name, s)| {
            let stage =
                name.trim_start_matches("trace.").trim_end_matches("_us").to_string();
            (stage, s)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_record_nothing_and_enabled_spans_do() {
        let h = registry::histogram("trace.test_span_stage_us");
        let before = h.count();
        set_enabled(false);
        {
            let s = span("test_span_stage");
            assert!(!s.is_live());
        }
        assert_eq!(h.count(), before, "disabled span must be a no-op");
        record_stage("test_span_stage", 5);
        assert_eq!(h.count(), before, "disabled record_stage must be a no-op");

        set_enabled(true);
        {
            let outer = span("test_span_stage");
            assert!(outer.is_live());
            // Nested span of the same stage times its own interval.
            drop(span("test_span_stage"));
        }
        set_enabled(false);
        assert_eq!(h.count(), before + 2, "outer + nested spans both recorded");
        record_stage("other_stage_off", 1); // still disabled: no panic, no record
    }
}
