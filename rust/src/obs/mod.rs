//! Unified observability layer for the dist/persist/serve stack.
//!
//! Three pieces, one registry:
//!
//! - **Metrics registry** ([`registry`]): process-global named
//!   [`Counter`]s, [`Gauge`]s, and fixed log-bucket [`Histogram`]s.
//!   Components resolve handles once (through a [`Scope`], so multiple
//!   live instances keep distinct names) and update via relaxed
//!   atomics on hot paths. The pre-existing stat structs
//!   (`RouterStats`, `CacheStats`, `RowCacheStats`, `ServeDistStats`,
//!   ...) are now *views over registry reads* — there is no second set
//!   of counters behind them.
//! - **Stage-span tracing** ([`span`]): `obs::span("sample")` times a
//!   pipeline stage into `trace.sample_us`. Off by default; a disabled
//!   span costs one relaxed atomic load. `--metrics-out` (and the
//!   benches' stage-breakdown legs) turn it on.
//! - **JSONL telemetry export** ([`Exporter`]): periodic snapshots plus
//!   an end-of-run report, one JSON document per line, validated by
//!   `pyg2 obs-check`.
//!
//! Metric naming convention: `<layer>.<component>.<field>`, e.g.
//! `dist.router.remote_msgs`, `persist.row_cache.hits`,
//! `serve.requests`, `persist.io.read_us`, `trace.queue_wait_us`.
//! See the observability section of `rust/README.md` for the full
//! glossary and the JSONL schema.
//!
//! Nothing in this module consumes RNG state or reorders pipeline
//! work, so batch and prediction streams are seed-for-seed identical
//! with telemetry on or off (pinned by `tests/test_obs.rs`).

pub mod export;
pub mod hist;
pub mod registry;
pub mod span;

pub use export::{check_file, snapshot_json, Exporter};
pub use hist::{percentile_sorted, HistSnapshot, Histogram};
pub use registry::{counter, gauge, histogram, read_all, reset_traces, Counter, Gauge, Scope};
pub use span::{enabled, record_stage, set_enabled, span, stage_report, Span};
