//! JSONL telemetry export: periodic registry snapshots plus a final
//! end-of-run report, one JSON document per line.
//!
//! Wired as `--metrics-out FILE` / `--metrics-every SECS` on
//! `pyg2 dist` and `pyg2 serve-dist` (and consumed by the benches via
//! `PYG2_METRICS_OUT`). Each line is a complete snapshot:
//!
//! ```json
//! {"seq":0,"ts_ms":1042,"final":false,
//!  "counters":{"dist.router.remote_msgs":96,...},
//!  "gauges":{"persist.row_cache.bytes_cached":524288,...},
//!  "histograms":{"trace.sample_us":{"count":64,"sum":81920,
//!                "p50":1279,"p90":1535,"p95":1535,"p99":2047,"max":2047}}}
//! ```
//!
//! Timestamps are milliseconds since the exporter started (monotonic
//! clock), so output is reproducible modulo timing. Validation lives in
//! `pyg2 obs-check FILE`, which CI runs on every emitted file.

use super::registry;
use crate::error::Result;
use crate::util::json::Json;
use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One registry snapshot as a JSON document. `seq`/`ts_ms` stamp the
/// line's position in the run; `fin` marks the end-of-run report.
pub fn snapshot_json(seq: u64, ts_ms: u64, fin: bool) -> Json {
    let (counters, gauges, hists) = registry::read_all();
    let counters =
        Json::Obj(counters.into_iter().map(|(k, v)| (k, Json::num(v as f64))).collect());
    let gauges =
        Json::Obj(gauges.into_iter().map(|(k, v)| (k, Json::num(v as f64))).collect());
    let hists = Json::Obj(
        hists
            .into_iter()
            .map(|(k, s)| {
                (
                    k,
                    Json::obj(vec![
                        ("count", Json::num(s.count as f64)),
                        ("sum", Json::num(s.sum as f64)),
                        ("p50", Json::num(s.p50 as f64)),
                        ("p90", Json::num(s.p90 as f64)),
                        ("p95", Json::num(s.p95 as f64)),
                        ("p99", Json::num(s.p99 as f64)),
                        ("max", Json::num(s.max as f64)),
                    ]),
                )
            })
            .collect(),
    );
    Json::obj(vec![
        ("seq", Json::num(seq as f64)),
        ("ts_ms", Json::num(ts_ms as f64)),
        ("final", Json::Bool(fin)),
        ("counters", counters),
        ("gauges", gauges),
        ("histograms", hists),
    ])
}

fn append_line(file: &mut File, line: &Json) -> std::io::Result<()> {
    file.write_all(line.to_string().as_bytes())?;
    file.write_all(b"\n")?;
    file.flush()
}

/// Periodic + final JSONL snapshot writer. `start` truncates the file;
/// [`Exporter::finish`] (or drop) writes the end-of-run report.
pub struct Exporter {
    path: PathBuf,
    started: Instant,
    seq: Arc<AtomicU64>,
    stop: Arc<(Mutex<bool>, Condvar)>,
    ticker: Option<JoinHandle<()>>,
    finished: bool,
}

impl Exporter {
    /// Begin exporting to `path`. With `every = Some(d)`, a background
    /// thread appends a snapshot line each period until `finish`.
    pub fn start(path: &Path, every: Option<Duration>) -> Result<Self> {
        File::create(path)?; // truncate up front so a crash leaves no stale run
        let started = Instant::now();
        let seq = Arc::new(AtomicU64::new(0));
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let ticker = match every {
            None => None,
            Some(period) => {
                let (path, seq, stop) = (path.to_path_buf(), Arc::clone(&seq), Arc::clone(&stop));
                Some(std::thread::spawn(move || {
                    let mut file = match OpenOptions::new().append(true).open(&path) {
                        Ok(f) => f,
                        Err(_) => return,
                    };
                    let (lock, cv) = &*stop;
                    let mut stopped = lock.lock().unwrap();
                    loop {
                        let (guard, timeout) = cv.wait_timeout(stopped, period).unwrap();
                        stopped = guard;
                        if *stopped {
                            return;
                        }
                        if timeout.timed_out() {
                            let line = snapshot_json(
                                seq.fetch_add(1, Ordering::Relaxed),
                                started.elapsed().as_millis() as u64,
                                false,
                            );
                            let _ = append_line(&mut file, &line);
                        }
                    }
                }))
            }
        };
        Ok(Self { path: path.to_path_buf(), started, seq, stop, ticker, finished: false })
    }

    fn stop_ticker(&mut self) {
        if let Some(h) = self.ticker.take() {
            let (lock, cv) = &*self.stop;
            *lock.lock().unwrap() = true;
            cv.notify_all();
            let _ = h.join();
        }
    }

    fn write_final(&self) -> std::io::Result<()> {
        let mut file = OpenOptions::new().append(true).open(&self.path)?;
        let line = snapshot_json(
            self.seq.fetch_add(1, Ordering::Relaxed),
            self.started.elapsed().as_millis() as u64,
            true,
        );
        append_line(&mut file, &line)
    }

    /// Stop the ticker and append the end-of-run report.
    pub fn finish(mut self) -> Result<()> {
        self.stop_ticker();
        self.finished = true; // drop must not write a second report
        self.write_final()?;
        Ok(())
    }
}

impl Drop for Exporter {
    fn drop(&mut self) {
        // Best-effort final report if `finish` was never called.
        self.stop_ticker();
        if !self.finished {
            let _ = self.write_final();
        }
    }
}

/// Validate a JSONL telemetry file: non-empty, every line parses, and
/// every line carries the snapshot schema keys. Returns the line count
/// (what `pyg2 obs-check` prints). Errors name the offending line.
pub fn check_file(path: &Path) -> Result<usize> {
    let text = std::fs::read_to_string(path)?;
    // A writer dying mid-record leaves a final line with no newline;
    // `lines()` would hand it to the JSON parser looking complete (or,
    // worse, parsing cleanly as a prefix record), so reject it up front.
    if !text.is_empty() && !text.ends_with('\n') {
        return Err(crate::error::Error::Storage(format!(
            "{}: final line truncated mid-record (no trailing newline)",
            path.display()
        )));
    }
    let mut lines = 0usize;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = crate::util::json::parse(line).map_err(|e| {
            crate::error::Error::Storage(format!("{}:{}: bad JSON: {e}", path.display(), i + 1))
        })?;
        for key in ["seq", "ts_ms", "final", "counters", "gauges", "histograms"] {
            if v.get(key).is_none() {
                return Err(crate::error::Error::Storage(format!(
                    "{}:{}: snapshot missing key {key:?}",
                    path.display(),
                    i + 1
                )));
            }
        }
        lines += 1;
    }
    if lines == 0 {
        return Err(crate::error::Error::Storage(format!(
            "{}: no telemetry snapshots",
            path.display()
        )));
    }
    Ok(lines)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_roundtrips_through_json() {
        registry::counter("test.export.c").add(7);
        registry::histogram("test.export.h").record(100);
        let v = snapshot_json(3, 1234, true);
        let r = crate::util::json::parse(&v.to_string()).unwrap();
        assert_eq!(r.get("seq").unwrap().as_f64(), Some(3.0));
        assert_eq!(r.get("final").unwrap().as_bool(), Some(true));
        let c = r.get("counters").unwrap().get("test.export.c").unwrap();
        assert!(c.as_f64().unwrap() >= 7.0);
        let h = r.get("histograms").unwrap().get("test.export.h").unwrap();
        assert!(h.get("count").unwrap().as_f64().unwrap() >= 1.0);
        assert!(h.get("p99").is_some() && h.get("p50").is_some());
    }

    #[test]
    fn exporter_writes_final_line_and_check_accepts_it() {
        let dir = std::env::temp_dir().join(format!("pyg2_obs_export_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("metrics.jsonl");
        let ex = Exporter::start(&path, None).unwrap();
        registry::counter("test.export.final").inc();
        ex.finish().unwrap();
        let n = check_file(&path).unwrap();
        assert_eq!(n, 1, "one final snapshot line");
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("final").unwrap().as_bool(), Some(true));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn check_rejects_empty_and_garbage() {
        let dir = std::env::temp_dir().join(format!("pyg2_obs_check_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let empty = dir.join("empty.jsonl");
        std::fs::write(&empty, "").unwrap();
        assert!(check_file(&empty).is_err());
        let garbage = dir.join("garbage.jsonl");
        std::fs::write(&garbage, "not json\n").unwrap();
        assert!(check_file(&garbage).is_err());
        let missing = dir.join("missing.jsonl");
        std::fs::write(&missing, "{\"seq\":0}\n").unwrap();
        assert!(check_file(&missing).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn check_rejects_final_line_truncated_mid_record() {
        let dir = std::env::temp_dir().join(format!("pyg2_obs_trunc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("metrics.jsonl");
        let ex = Exporter::start(&path, None).unwrap();
        ex.finish().unwrap();
        assert!(check_file(&path).is_ok(), "intact file must validate");
        // Chop the trailing bytes off the last record, as a killed
        // writer would.
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() - 20]).unwrap();
        let err = check_file(&path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "unexpected error: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn exporter_start_on_unwritable_path_is_a_clean_error() {
        let bad = Path::new("/nonexistent-dir/metrics.jsonl");
        match Exporter::start(bad, None) {
            Err(crate::error::Error::Io(_)) => {}
            other => panic!("expected a clean I/O error, got {other:?}"),
        }
    }
}
