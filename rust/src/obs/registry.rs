//! Process-global metrics registry: named counters, gauges, and
//! log-bucket histograms, registered once and updated via relaxed
//! atomics on hot paths.
//!
//! Components do **not** look metrics up by name on the hot path: they
//! resolve [`Counter`]/[`Gauge`]/[`Histogram`] handles (plain `Arc`s)
//! at construction and update through those. The registry mutex is
//! only taken at registration and snapshot time.
//!
//! Because several live instances of one component are routine (one
//! `PartitionRouter` per rank and edge type, one `RowCache` per mount,
//! parallel unit tests in one process), components register through a
//! [`Scope`]: the first instance of a prefix owns the canonical plain
//! names (`dist.router.remote_msgs`), later instances get a
//! disambiguating `#n` suffix on the prefix (`dist.router#2.*`). Each
//! instance keeps its own handles, so per-instance `stats()` views and
//! `reset_stats()` behave exactly as before the registry existed.

use super::hist::Histogram;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A monotone event counter (resettable for per-phase bench readings).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// A point-in-time signed value (cache occupancy, queue depth, ...),
/// updated by delta so concurrent writers compose.
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn add(&self, delta: i64) {
        self.0.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn sub(&self, delta: i64) {
        self.0.fetch_sub(delta, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    hists: BTreeMap<String, Arc<Histogram>>,
    /// Live instance count per scope prefix, for `#n` disambiguation.
    scopes: BTreeMap<String, u64>,
}

fn registry() -> &'static Mutex<Inner> {
    static REGISTRY: OnceLock<Mutex<Inner>> = OnceLock::new();
    REGISTRY.get_or_init(Mutex::default)
}

/// Get-or-register the counter `name`. Same name → same handle.
pub fn counter(name: &str) -> Arc<Counter> {
    let mut r = registry().lock().unwrap();
    Arc::clone(r.counters.entry(name.to_string()).or_default())
}

/// Get-or-register the gauge `name`.
pub fn gauge(name: &str) -> Arc<Gauge> {
    let mut r = registry().lock().unwrap();
    Arc::clone(r.gauges.entry(name.to_string()).or_default())
}

/// Get-or-register the histogram `name`.
pub fn histogram(name: &str) -> Arc<Histogram> {
    let mut r = registry().lock().unwrap();
    Arc::clone(r.hists.entry(name.to_string()).or_default())
}

/// One component instance's naming scope. See the module docs for the
/// canonical-name / `#n`-suffix convention.
pub struct Scope {
    prefix: String,
}

impl Scope {
    /// Claim the next instance of `prefix` (e.g. `"persist.row_cache"`).
    pub fn new(prefix: &str) -> Self {
        let n = {
            let mut r = registry().lock().unwrap();
            let slot = r.scopes.entry(prefix.to_string()).or_insert(0);
            *slot += 1;
            *slot
        };
        let prefix =
            if n == 1 { prefix.to_string() } else { format!("{prefix}#{n}") };
        Self { prefix }
    }

    /// The resolved (possibly `#n`-suffixed) prefix.
    pub fn prefix(&self) -> &str {
        &self.prefix
    }

    pub fn counter(&self, field: &str) -> Arc<Counter> {
        counter(&format!("{}.{field}", self.prefix))
    }

    pub fn gauge(&self, field: &str) -> Arc<Gauge> {
        gauge(&format!("{}.{field}", self.prefix))
    }

    pub fn histogram(&self, field: &str) -> Arc<Histogram> {
        histogram(&format!("{}.{field}", self.prefix))
    }
}

/// Relaxed point-in-time copy of every registered metric, in name
/// order: `(counters, gauges, histogram snapshots)`.
#[allow(clippy::type_complexity)]
pub fn read_all() -> (
    Vec<(String, u64)>,
    Vec<(String, i64)>,
    Vec<(String, super::hist::HistSnapshot)>,
) {
    let r = registry().lock().unwrap();
    let counters = r.counters.iter().map(|(k, v)| (k.clone(), v.get())).collect();
    let gauges = r.gauges.iter().map(|(k, v)| (k.clone(), v.get())).collect();
    let hists = r.hists.iter().map(|(k, v)| (k.clone(), v.snapshot())).collect();
    (counters, gauges, hists)
}

/// Zero every `trace.*` stage histogram (bench legs measure per-phase
/// stage breakdowns). Counters and gauges are left alone — counters
/// belong to component instances (reset via their `reset_stats()`),
/// and gauges carry live occupancy state that must not be clobbered.
pub fn reset_traces() {
    let r = registry().lock().unwrap();
    for (name, h) in r.hists.iter() {
        if name.starts_with("trace.") {
            h.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_name_returns_same_handle() {
        let a = counter("test.registry.same_name");
        let b = counter("test.registry.same_name");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        assert!(Arc::ptr_eq(&a, &b));
        a.reset();
        assert_eq!(b.get(), 0);
    }

    #[test]
    fn gauge_deltas_compose() {
        let g = gauge("test.registry.gauge");
        g.add(10);
        g.sub(3);
        assert_eq!(g.get(), 7);
        g.set(-1);
        assert_eq!(g.get(), -1);
    }

    #[test]
    fn scopes_disambiguate_instances() {
        let a = Scope::new("test.registry.scoped");
        let b = Scope::new("test.registry.scoped");
        assert_eq!(a.prefix(), "test.registry.scoped");
        assert_eq!(b.prefix(), "test.registry.scoped#2");
        let ca = a.counter("hits");
        let cb = b.counter("hits");
        assert!(!Arc::ptr_eq(&ca, &cb), "instances must not share counters");
        ca.inc();
        assert_eq!((ca.get(), cb.get()), (1, 0));
    }

    #[test]
    fn read_all_sees_registered_metrics() {
        counter("test.registry.read_all.c").add(5);
        gauge("test.registry.read_all.g").set(9);
        histogram("test.registry.read_all.h").record(100);
        let (cs, gs, hs) = read_all();
        assert!(cs.iter().any(|(k, v)| k == "test.registry.read_all.c" && *v >= 5));
        assert!(gs.iter().any(|(k, v)| k == "test.registry.read_all.g" && *v == 9));
        assert!(hs.iter().any(|(k, s)| k == "test.registry.read_all.h" && s.count >= 1));
    }
}
