//! Row-major dense tensors (`f32` and `i64`) for host-side batch assembly.

use crate::error::{Error, Result};

/// Row-major `f32` tensor with arbitrary rank.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                numel,
                data.len()
            )));
        }
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let numel = shape.iter().product();
        Self { shape, data: vec![0.0; numel] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let numel = shape.iter().product();
        Self { shape, data: vec![v; numel] }
    }

    /// Glorot-style uniform init in `[-limit, limit]` (weight init for the
    /// host-owned model parameters that feed the train-step HLO).
    pub fn glorot(rows: usize, cols: usize, rng: &mut crate::util::Rng) -> Self {
        let limit = (6.0 / (rows + cols) as f64).sqrt() as f32;
        let data = (0..rows * cols)
            .map(|_| (rng.f32() * 2.0 - 1.0) * limit)
            .collect();
        Self { shape: vec![rows, cols], data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows (first dimension); 2-D accessors below.
    pub fn rows(&self) -> usize {
        self.shape.first().copied().unwrap_or(0)
    }

    pub fn cols(&self) -> usize {
        self.shape.get(1).copied().unwrap_or(1)
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols() + c]
    }

    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        let cols = self.cols();
        self.data[r * cols + c] = v;
    }

    /// Gather rows by index into a new tensor (feature fetch join).
    pub fn gather_rows(&self, idx: &[usize]) -> Result<Tensor> {
        let c = self.cols();
        let mut data = Vec::with_capacity(idx.len() * c);
        for &i in idx {
            if i >= self.rows() {
                return Err(Error::Shape(format!("row {} out of {}", i, self.rows())));
            }
            data.extend_from_slice(self.row(i));
        }
        Tensor::new(vec![idx.len(), c], data)
    }

    /// Zero-pad (or truncate) the first dimension to exactly `n` rows —
    /// the static-shape bucketing step before HLO execution.
    pub fn pad_rows(&self, n: usize) -> Tensor {
        let c = self.cols();
        let mut data = self.data.clone();
        data.resize(n * c, 0.0);
        Tensor { shape: vec![n, c], data }
    }

    /// Write rows gathered from `src` at `idx` into `self[0..idx.len()]`
    /// without allocating (loader hot-path variant of `gather_rows`).
    pub fn gather_rows_into(&mut self, src: &Tensor, idx: &[usize]) -> Result<()> {
        let c = self.cols();
        if src.cols() != c {
            return Err(Error::Shape(format!("cols {} != {}", src.cols(), c)));
        }
        if idx.len() > self.rows() {
            return Err(Error::Shape(format!("{} rows > capacity {}", idx.len(), self.rows())));
        }
        // Validate before writing so errors leave `self` untouched.
        if let Some(&bad) = idx.iter().find(|&&i| i >= src.rows()) {
            return Err(Error::Shape(format!("row {} out of {}", bad, src.rows())));
        }
        for (out_r, &i) in idx.iter().enumerate() {
            let dst_off = out_r * c;
            self.data[dst_off..dst_off + c].copy_from_slice(src.row(i));
        }
        // Zero the padding tail so stale rows never leak across batches.
        for r in idx.len()..self.rows() {
            self.row_mut(r).fill(0.0);
        }
        Ok(())
    }

    /// Stack tensors along a new leading axis.
    pub fn stack(parts: &[&Tensor]) -> Result<Tensor> {
        let first = parts.first().ok_or_else(|| Error::Shape("stack of nothing".into()))?;
        let mut data = Vec::with_capacity(parts.len() * first.numel());
        for p in parts {
            if p.shape != first.shape {
                return Err(Error::Shape("stack shape mismatch".into()));
            }
            data.extend_from_slice(&p.data);
        }
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(&first.shape);
        Tensor::new(shape, data)
    }

    pub fn reshape(&self, shape: Vec<usize>) -> Result<Tensor> {
        Tensor::new(shape, self.data.clone())
    }
}

/// Row-major `i64` tensor (edge indices, node ids, labels, masks).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI64 {
    shape: Vec<usize>,
    data: Vec<i64>,
}

impl TensorI64 {
    pub fn new(shape: Vec<usize>, data: Vec<i64>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                numel,
                data.len()
            )));
        }
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let numel = shape.iter().product();
        Self { shape, data: vec![0; numel] }
    }

    pub fn from_vec(data: Vec<i64>) -> Self {
        Self { shape: vec![data.len()], data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i64] {
        &mut self.data
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Pad (with `fill`) or truncate the last dimension to `n`.
    pub fn pad_to(&self, n: usize, fill: i64) -> TensorI64 {
        let mut data = self.data.clone();
        data.resize(n, fill);
        TensorI64 { shape: vec![n], data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn gather_and_pad() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let g = t.gather_rows(&[2, 0]).unwrap();
        assert_eq!(g.data(), &[5., 6., 1., 2.]);
        let p = g.pad_rows(4);
        assert_eq!(p.shape(), &[4, 2]);
        assert_eq!(&p.data()[4..], &[0.0; 4]);
        assert!(t.gather_rows(&[3]).is_err());
    }

    #[test]
    fn gather_rows_into_zeroes_tail() {
        let src = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        let mut dst = Tensor::full(vec![3, 2], 9.0);
        dst.gather_rows_into(&src, &[1]).unwrap();
        assert_eq!(dst.data(), &[3., 4., 0., 0., 0., 0.]);
    }

    #[test]
    fn stack_checks_shapes() {
        let a = Tensor::zeros(vec![2, 2]);
        let b = Tensor::zeros(vec![2, 2]);
        let s = Tensor::stack(&[&a, &b]).unwrap();
        assert_eq!(s.shape(), &[2, 2, 2]);
        let c = Tensor::zeros(vec![3, 2]);
        assert!(Tensor::stack(&[&a, &c]).is_err());
    }

    #[test]
    fn glorot_within_limit() {
        let mut rng = crate::util::Rng::new(1);
        let w = Tensor::glorot(16, 32, &mut rng);
        let limit = (6.0f64 / 48.0).sqrt() as f32 + 1e-6;
        assert!(w.data().iter().all(|x| x.abs() <= limit));
    }

    #[test]
    fn i64_pad() {
        let t = TensorI64::from_vec(vec![5, 6]);
        let p = t.pad_to(4, -1);
        assert_eq!(p.data(), &[5, 6, -1, -1]);
    }
}
