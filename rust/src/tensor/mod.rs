//! Host-side dense tensors.
//!
//! The coordinator needs a small tensor type to slice features, pad
//! mini-batches to the static shapes the AOT-compiled HLO expects, and to
//! marshal data into `xla::Literal`s. Only the operations the pipeline hot
//! path needs are implemented; anything numerical beyond that lives in the
//! compiled HLO (L2/L1), never on the host.

mod dense;
mod ops;

pub use dense::{Tensor, TensorI64};
pub use ops::{argmax_checked, argmax_rows, cosine_similarity, l2_normalize_rows, softmax_row, topk};
