//! Host-side numeric helpers used by post-processing (metrics, MIPS, RAG).
//!
//! Deliberately small: the training/inference math runs inside compiled
//! HLO; these exist for evaluation and retrieval bookkeeping only.

use super::Tensor;

/// Argmax per row (predictions from a logits matrix).
pub fn argmax_rows(t: &Tensor) -> Vec<usize> {
    (0..t.rows())
        .map(|r| {
            let row = t.row(r);
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

/// Argmax over one logits row that refuses malformed input: returns
/// `None` if the slice is empty or any entry is NaN, so callers (the
/// inference serve loop) can turn a bad model output into an error
/// reply instead of a panic.
pub fn argmax_checked(xs: &[f32]) -> Option<usize> {
    if xs.is_empty() || xs.iter().any(|x| x.is_nan()) {
        return None;
    }
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
}

/// In-place row-wise L2 normalization (embedding preprocessing for MIPS).
pub fn l2_normalize_rows(t: &mut Tensor) {
    for r in 0..t.rows() {
        let row = t.row_mut(r);
        let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > 1e-12 {
            for x in row {
                *x /= norm;
            }
        }
    }
}

/// Softmax of a single row/slice.
pub fn softmax_row(xs: &[f32]) -> Vec<f32> {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = xs.iter().map(|x| (x - m).exp()).collect();
    let s: f32 = exps.iter().sum();
    exps.iter().map(|e| e / s.max(1e-12)).collect()
}

/// Cosine similarity of two equal-length vectors.
pub fn cosine_similarity(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f32, 0.0f32, 0.0f32);
    for (x, y) in a.iter().zip(b) {
        dot += x * y;
        na += x * x;
        nb += y * y;
    }
    dot / (na.sqrt() * nb.sqrt()).max(1e-12)
}

/// Indices of the `k` largest scores, descending (stable for ties by index).
pub fn topk(scores: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_per_row() {
        let t = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.0, 2.0, -1.0, 1.0]).unwrap();
        assert_eq!(argmax_rows(&t), vec![1, 0]);
    }

    #[test]
    fn argmax_checked_rejects_nan_and_empty() {
        assert_eq!(argmax_checked(&[0.1, 0.9, 0.0]), Some(1));
        assert_eq!(argmax_checked(&[2.0, -1.0]), Some(0));
        assert_eq!(argmax_checked(&[0.1, f32::NAN]), None);
        assert_eq!(argmax_checked(&[]), None);
        // Infinities are orderable, not malformed.
        assert_eq!(argmax_checked(&[f32::NEG_INFINITY, 3.0, f32::INFINITY]), Some(2));
    }

    #[test]
    fn l2_norm_makes_unit_rows() {
        let mut t = Tensor::new(vec![1, 2], vec![3.0, 4.0]).unwrap();
        l2_normalize_rows(&mut t);
        assert!((t.row(0)[0] - 0.6).abs() < 1e-6);
        assert!((t.row(0)[1] - 0.8).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let p = softmax_row(&[1000.0, 1000.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!((p[0] - 0.5).abs() < 1e-5);
    }

    #[test]
    fn cosine_of_parallel_is_one() {
        assert!((cosine_similarity(&[1.0, 2.0], &[2.0, 4.0]) - 1.0).abs() < 1e-6);
        assert!(cosine_similarity(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6);
    }

    #[test]
    fn topk_descending_stable() {
        assert_eq!(topk(&[0.1, 0.9, 0.5, 0.9], 3), vec![1, 3, 2]);
        assert_eq!(topk(&[0.1], 5), vec![0]);
    }
}
