//! Timing statistics for the bench harness and pipeline metrics.

use std::time::Duration;

/// Accumulates samples (in seconds) and reports summary statistics.
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn push_duration(&mut self, d: Duration) {
        self.xs.push(d.as_secs_f64());
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let v = self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (self.xs.len() - 1) as f64;
        v.sqrt()
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile of the samples — delegates to the codebase's single
    /// percentile definition in [`crate::obs::hist::percentile_sorted`]
    /// (linear interpolation on the sorted samples).
    pub fn percentile(&self, p: f64) -> f64 {
        crate::obs::hist::percentile_sorted(&self.xs, p)
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Render as a one-line human summary in milliseconds.
    pub fn summary_ms(&self) -> String {
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms min={:.3}ms max={:.3}ms",
            self.len(),
            self.mean() * 1e3,
            self.median() * 1e3,
            self.percentile(95.0) * 1e3,
            self.min() * 1e3,
            self.max() * 1e3,
        )
    }
}

/// Online counter histogram over the shared log-bucket layout of
/// [`crate::obs::hist`]; cheap enough for hot-loop instrumentation
/// (loader queue depths, batch sizes, ...). The atomic multi-thread
/// variant is [`crate::obs::Histogram`]; both share one bucket layout
/// and one quantile definition.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; crate::obs::hist::NUM_BUCKETS], count: 0, sum: 0.0 }
    }

    pub fn record(&mut self, v: u64) {
        self.buckets[crate::obs::hist::bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v as f64;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Upper bound of the bucket containing the q-quantile (`q` in
    /// 0..=1) — the shared deterministic readout of
    /// [`crate::obs::hist::quantile_from_counts`].
    pub fn quantile_upper_bound(&self, q: f64) -> u64 {
        if self.count == 0 {
            return u64::MAX;
        }
        crate::obs::hist::quantile_from_counts(&self.buckets, q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_of_known_values() {
        let mut s = Samples::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut s = Samples::new();
        for x in 1..=100 {
            s.push(x as f64);
        }
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!(s.percentile(95.0) > 94.0 && s.percentile(95.0) < 96.5);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new();
        for v in 0..1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 499.5).abs() < 1e-9);
        assert!(h.quantile_upper_bound(0.5) >= 256);
        assert!(h.quantile_upper_bound(1.0) >= 512);
    }

    #[test]
    fn percentiles_pin_the_shared_definition() {
        // `Samples::percentile` delegates to obs::hist::percentile_sorted;
        // pin exact values so the two can never drift apart silently.
        let mut s = Samples::new();
        for x in 1..=100 {
            s.push(x as f64);
        }
        assert!((s.percentile(99.0) - 99.01).abs() < 1e-9);
        assert!((s.percentile(95.0) - 95.05).abs() < 1e-9);
        assert_eq!(
            s.percentile(99.0),
            crate::obs::hist::percentile_sorted(&(1..=100).map(f64::from).collect::<Vec<_>>(), 99.0)
        );
        // The histogram side shares one bucket layout: quantiles of a
        // known distribution are pinned to exact bucket upper bounds.
        let mut h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.quantile_upper_bound(0.5), 511);
        assert_eq!(h.quantile_upper_bound(0.99), 991);
        assert_eq!(h.quantile_upper_bound(1.0), 1023);
    }

    #[test]
    fn empty_samples_are_nan_safe() {
        let s = Samples::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
        assert!(s.is_empty());
    }
}
