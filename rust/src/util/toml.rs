//! TOML-subset parser for run configuration files.
//!
//! The config system (see `crate::config`) consumes `[section]` tables with
//! `key = value` entries where values are strings, integers, floats, bools,
//! or flat arrays thereof. That subset covers every config this framework
//! ships; nested tables-in-arrays and datetimes are intentionally not
//! supported and produce a clear error.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// `section -> key -> value`. Keys outside any `[section]` live under "".
pub type Document = BTreeMap<String, BTreeMap<String, Value>>;

/// Parse a TOML-subset document.
pub fn parse(input: &str) -> Result<Document, String> {
    let mut doc: Document = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();

    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty section name", lineno + 1));
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
        let key = line[..eq].trim();
        let val_src = line[eq + 1..].trim();
        if key.is_empty() || val_src.is_empty() {
            return Err(format!("line {}: empty key or value", lineno + 1));
        }
        let value = parse_value(val_src).map_err(|e| format!("line {}: {}", lineno + 1, e))?;
        // The section table normally exists (created at the header line
        // or the "" preamble above), but create it here rather than
        // trust that invariant with an unwrap.
        doc.entry(section.clone()).or_default().insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(src: &str) -> Result<Value, String> {
    let src = src.trim();
    if let Some(inner) = src.strip_prefix('"') {
        let end = inner
            .find('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if !inner[end + 1..].trim().is_empty() {
            return Err("trailing characters after string".into());
        }
        return Ok(Value::Str(inner[..end].to_string()));
    }
    if let Some(inner) = src.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut out = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                out.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Arr(out));
    }
    match src {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = src.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = src.replace('_', "").parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("unsupported value `{src}` (subset: str/int/float/bool/flat array)"))
}

/// Split an array body on commas that are not inside strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if !s[start..].trim().is_empty() {
        parts.push(&s[start..]);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
            title = "run1"   # top-level
            [train]
            steps = 300
            lr = 0.01
            use_trim = true
            fanouts = [10, 5]
            names = ["a", "b"]
            "#,
        )
        .unwrap();
        assert_eq!(doc[""]["title"].as_str(), Some("run1"));
        assert_eq!(doc["train"]["steps"].as_i64(), Some(300));
        assert_eq!(doc["train"]["lr"].as_f64(), Some(0.01));
        assert_eq!(doc["train"]["use_trim"].as_bool(), Some(true));
        let f = doc["train"]["fanouts"].as_arr().unwrap();
        assert_eq!(f.len(), 2);
        assert_eq!(f[0].as_i64(), Some(10));
        assert_eq!(doc["train"]["names"].as_arr().unwrap()[1].as_str(), Some("b"));
    }

    #[test]
    fn hash_in_string_is_not_comment() {
        let doc = parse("x = \"a#b\"").unwrap();
        assert_eq!(doc[""]["x"].as_str(), Some("a#b"));
    }

    #[test]
    fn errors_are_line_numbered() {
        let err = parse("a = 1\nb ==").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn rejects_unsupported() {
        assert!(parse("x = 1979-05-27").is_err());
        assert!(parse("[a\nb = 1").is_err());
    }

    #[test]
    fn repeated_section_headers_accumulate_without_panicking() {
        // Re-entering a section (and keys after a section that was first
        // declared empty) must insert into the existing table — the
        // regression here was an unwrap on the section lookup.
        let doc = parse(
            r#"
            [a]
            x = 1
            [b]
            [a]
            y = 2
            [b]
            z = 3
            "#,
        )
        .unwrap();
        assert_eq!(doc["a"]["x"].as_i64(), Some(1));
        assert_eq!(doc["a"]["y"].as_i64(), Some(2));
        assert_eq!(doc["b"]["z"].as_i64(), Some(3));
    }

    #[test]
    fn underscored_numbers() {
        let doc = parse("n = 1_000_000").unwrap();
        assert_eq!(doc[""]["n"].as_i64(), Some(1_000_000));
    }
}
