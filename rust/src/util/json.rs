//! Minimal JSON reader/writer.
//!
//! `serde` is unavailable offline, and the repo needs JSON in two places:
//! reading `artifacts/manifest.json` (produced by the Python AOT step) and
//! writing machine-readable bench reports. This module implements a small,
//! strict JSON value model sufficient for both.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]`-style access that tolerates missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// A required non-negative integral "size" field of a JSON object:
/// present, numeric, finite, fraction-free, and small enough to be
/// exact in an f64. The one validator behind every size read from
/// untrusted metadata (`.pygf` headers, partition-bundle manifests).
pub fn uint_field(v: &Json, field: &str) -> Result<u64, String> {
    let n = v
        .get(field)
        .and_then(|f| f.as_f64())
        .ok_or_else(|| format!("missing numeric field {field}"))?;
    if !n.is_finite() || n < 0.0 || n.fract() != 0.0 || n > (1u64 << 52) as f64 {
        return Err(format!("field {field}={n} is not a valid size"));
    }
    Ok(n as u64)
}

/// Parse a JSON document. Strict except that it allows trailing whitespace.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, "x\ny", true, null], "b": {"c": -3}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_f64(), Some(-3.0));
        let reparsed = parse(&v.to_string()).unwrap();
        assert_eq!(v, reparsed);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::str("quote\" slash\\ nl\n tab\t");
        let r = parse(&v.to_string()).unwrap();
        assert_eq!(v, r);
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn integer_formatting_is_exact() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(2.5).to_string(), "2.5");
    }

    #[test]
    fn uint_field_accepts_sizes_and_rejects_everything_else() {
        let v = parse(r#"{"n":80,"zero":0,"neg":-1,"frac":2.5,"big":1e300,"s":"80"}"#).unwrap();
        assert_eq!(uint_field(&v, "n"), Ok(80));
        assert_eq!(uint_field(&v, "zero"), Ok(0));
        for bad in ["neg", "frac", "big", "s", "absent"] {
            assert!(uint_field(&v, bad).is_err(), "{bad} must be rejected");
        }
    }
}
