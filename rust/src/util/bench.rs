//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, calibrated iteration counts, percentile reporting and a
//! machine-readable JSON report, which the `rust/benches/*` binaries use to
//! regenerate the paper's tables.

use super::json::Json;
use super::stats::Samples;
use std::time::{Duration, Instant};

/// One benchmark's configuration.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Wall-clock budget for the warmup phase.
    pub warmup: Duration,
    /// Wall-clock budget for the measurement phase.
    pub measure: Duration,
    /// Minimum number of measured samples regardless of budget.
    pub min_samples: usize,
    /// Maximum number of measured samples.
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_samples: 10,
            max_samples: 1000,
        }
    }
}

impl BenchConfig {
    /// A faster profile for CI/tests.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            min_samples: 5,
            max_samples: 200,
        }
    }

    /// Honour `PYG2_BENCH_QUICK` for fast smoke runs (see
    /// `rust/README.md`): any truthy value — `1`, `true`, `yes`, `on`,
    /// or anything else non-empty that is not an explicit falsy
    /// `0`/`false`/`no`/`off` — selects [`BenchConfig::quick`].
    pub fn from_env() -> Self {
        Self::from_env_value(std::env::var("PYG2_BENCH_QUICK").ok().as_deref())
    }

    /// [`BenchConfig::from_env`]'s decision, factored out of the process
    /// environment for testability.
    fn from_env_value(value: Option<&str>) -> Self {
        let truthy = value.is_some_and(|v| {
            let v = v.trim().to_ascii_lowercase();
            !v.is_empty() && !matches!(v.as_str(), "0" | "false" | "no" | "off")
        });
        if truthy {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// Result of a single benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Samples,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.samples.mean() * 1e3
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("n", Json::num(self.samples.len() as f64)),
            ("mean_ms", Json::num(self.samples.mean() * 1e3)),
            ("p50_ms", Json::num(self.samples.median() * 1e3)),
            ("p95_ms", Json::num(self.samples.percentile(95.0) * 1e3)),
            ("min_ms", Json::num(self.samples.min() * 1e3)),
            ("max_ms", Json::num(self.samples.max() * 1e3)),
        ])
    }
}

/// A group of benchmarks printed as an aligned table plus JSON report.
pub struct BenchSuite {
    pub title: String,
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(title: impl Into<String>) -> Self {
        Self { title: title.into(), cfg: BenchConfig::from_env(), results: Vec::new() }
    }

    pub fn with_config(title: impl Into<String>, cfg: BenchConfig) -> Self {
        Self { title: title.into(), cfg, results: Vec::new() }
    }

    /// Run `f` under warmup + measurement and record the result.
    pub fn bench(&mut self, name: impl Into<String>, mut f: impl FnMut()) -> &BenchResult {
        let name = name.into();
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.cfg.warmup {
            f();
        }
        // Measure.
        let mut samples = Samples::new();
        let start = Instant::now();
        while (start.elapsed() < self.cfg.measure || samples.len() < self.cfg.min_samples)
            && samples.len() < self.cfg.max_samples
        {
            let t = Instant::now();
            f();
            samples.push_duration(t.elapsed());
        }
        eprintln!("  {:<44} {}", name, samples.summary_ms());
        self.results.push(BenchResult { name, samples });
        self.results.last().unwrap()
    }

    /// Record an externally computed scalar metric (e.g. accuracy) so it
    /// lands in the JSON report alongside the timings.
    pub fn record_metric(&mut self, name: impl Into<String>, value: f64) {
        let mut s = Samples::new();
        s.push(value);
        self.results.push(BenchResult { name: name.into(), samples: s });
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn find(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }

    /// Ratio of two benches' mean times: `a / b` (how much slower a is).
    pub fn speedup(&self, slow: &str, fast: &str) -> Option<f64> {
        Some(self.find(slow)?.samples.mean() / self.find(fast)?.samples.mean())
    }

    /// Print the summary table and write the JSON report file.
    pub fn finish(&self) {
        println!("\n== {} ==", self.title);
        println!("{:<44} {:>10} {:>10} {:>10}", "benchmark", "mean(ms)", "p50(ms)", "p95(ms)");
        for r in &self.results {
            println!(
                "{:<44} {:>10.3} {:>10.3} {:>10.3}",
                r.name,
                r.samples.mean() * 1e3,
                r.samples.median() * 1e3,
                r.samples.percentile(95.0) * 1e3
            );
        }
        let report = Json::obj(vec![
            ("suite", Json::str(self.title.clone())),
            ("results", Json::Arr(self.results.iter().map(|r| r.to_json()).collect())),
        ]);
        let dir = std::path::Path::new("bench_reports");
        let _ = std::fs::create_dir_all(dir);
        let fname = dir.join(format!(
            "{}.json",
            self.title.to_lowercase().replace([' ', ':', '/'], "_")
        ));
        if let Err(e) = std::fs::write(&fname, report.to_string()) {
            eprintln!("warn: could not write {}: {e}", fname.display());
        } else {
            println!("report: {}", fname.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_and_reports() {
        let mut suite = BenchSuite::with_config(
            "unit test suite",
            BenchConfig {
                warmup: Duration::from_millis(5),
                measure: Duration::from_millis(20),
                min_samples: 3,
                max_samples: 50,
            },
        );
        suite.bench("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        let r = suite.find("spin").unwrap();
        assert!(r.samples.len() >= 3);
        assert!(r.samples.mean() > 0.0);
    }

    #[test]
    fn env_quick_accepts_any_truthy_value() {
        let quick = BenchConfig::quick();
        for v in ["1", "true", "TRUE", "yes", "on", " 1 ", "quick", "2"] {
            let got = BenchConfig::from_env_value(Some(v));
            assert_eq!(got.measure, quick.measure, "{v:?} must select quick");
            assert_eq!(got.max_samples, quick.max_samples, "{v:?} must select quick");
        }
        let full = BenchConfig::default();
        for v in [None, Some(""), Some("0"), Some("false"), Some("No"), Some("OFF"), Some("  ")] {
            let got = BenchConfig::from_env_value(v);
            assert_eq!(got.measure, full.measure, "{v:?} must select default");
        }
    }

    #[test]
    fn speedup_ratio() {
        let mut suite = BenchSuite::with_config(
            "ratio",
            BenchConfig {
                warmup: Duration::from_millis(1),
                measure: Duration::from_millis(10),
                min_samples: 3,
                max_samples: 20,
            },
        );
        suite.bench("slow", || std::thread::sleep(Duration::from_micros(500)));
        suite.bench("fast", || std::thread::sleep(Duration::from_micros(100)));
        let s = suite.speedup("slow", "fast").unwrap();
        assert!(s > 1.5, "speedup={s}");
    }
}
