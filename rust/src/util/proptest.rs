//! Minimal property-based testing harness (proptest is unavailable offline).
//!
//! Supports seeded case generation and greedy shrinking of failing inputs.
//! Used by the coordinator-invariant tests (routing, batching, sampler
//! state) per the repro mandate.

use super::rng::Rng;

/// Number of cases per property (override with `PYG2_PROPTEST_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PYG2_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// A generator of random test inputs with an optional shrinker.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate "smaller" values, tried in order during shrinking.
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Run `prop` against `cases` generated inputs; on failure, shrink and panic
/// with the minimal failing case.
pub fn check<G: Gen>(seed: u64, gen: &G, prop: impl Fn(&G::Value) -> Result<(), String>) {
    let mut rng = Rng::new(seed);
    for case in 0..default_cases() {
        let input = gen.generate(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(gen, input, msg, &prop);
            panic!(
                "property failed (seed={seed}, case={case}): {min_msg}\nminimal input: {min_input:?}"
            );
        }
    }
}

fn shrink_loop<G: Gen>(
    gen: &G,
    mut input: G::Value,
    mut msg: String,
    prop: &impl Fn(&G::Value) -> Result<(), String>,
) -> (G::Value, String) {
    // Greedy: keep taking the first failing shrink candidate; bail after a
    // bounded number of rounds to stay fast.
    for _ in 0..200 {
        let mut progressed = false;
        for cand in gen.shrink(&input) {
            if let Err(m) = prop(&cand) {
                input = cand;
                msg = m;
                progressed = true;
                break;
            }
        }
        if !progressed {
            break;
        }
    }
    (input, msg)
}

/// Generator: `usize` in `[lo, hi]`, shrinking toward `lo`.
pub struct UsizeRange {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for UsizeRange {
    type Value = usize;

    fn generate(&self, rng: &mut Rng) -> usize {
        self.lo + rng.index(self.hi - self.lo + 1)
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Generator: vector with length in `[0, max_len]` of elements from `elem`,
/// shrinking by halving the vector then shrinking elements.
pub struct VecGen<G> {
    pub elem: G,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecGen<G> {
    type Value = Vec<G::Value>;

    fn generate(&self, rng: &mut Rng) -> Vec<G::Value> {
        let len = rng.index(self.max_len + 1);
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }

    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        if v.is_empty() {
            return out;
        }
        out.push(Vec::new());
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[1..].to_vec());
        // Shrink the first element as a representative.
        for s in self.elem.shrink(&v[0]) {
            let mut c = v.clone();
            c[0] = s;
            out.push(c);
        }
        out
    }
}

/// Generator: pair of independent generators.
pub struct PairGen<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, &UsizeRange { lo: 0, hi: 100 }, |&x| {
            if x <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "minimal input: 51")]
    fn failing_property_shrinks_to_minimum() {
        // Fails for x > 50; minimal failing case is 51.
        check(2, &UsizeRange { lo: 0, hi: 1000 }, |&x| {
            if x <= 50 {
                Ok(())
            } else {
                Err(format!("{x} > 50"))
            }
        });
    }

    #[test]
    fn vec_gen_respects_max_len() {
        let g = VecGen { elem: UsizeRange { lo: 0, hi: 9 }, max_len: 7 };
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let v = g.generate(&mut rng);
            assert!(v.len() <= 7);
            assert!(v.iter().all(|&x| x <= 9));
        }
    }
}
