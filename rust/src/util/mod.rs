//! Foundation utilities built from scratch for the offline sandbox:
//! PRNG, statistics, JSON, a TOML-subset config parser, thread pool +
//! bounded channels, a micro-bench harness, and a property-test framework.

pub mod bench;
pub mod json;
pub mod logging;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod toml;

pub use bench::{BenchConfig, BenchResult, BenchSuite};
pub use pool::{BoundedQueue, RecvDeadline, TaskHandle, ThreadPool};
pub use rng::{Rng, Zipf};
pub use stats::{Histogram, Samples};
