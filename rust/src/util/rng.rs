//! Deterministic pseudo-random number generation.
//!
//! The sandbox has no `rand` crate, and reproducibility of sampling is a
//! core requirement of the data pipeline (PyG seeds its C++ samplers the
//! same way), so we implement SplitMix64 (for seeding) and xoshiro256**
//! (for the bulk stream) from the public-domain reference algorithms.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: fast, high-quality generator for the sampling hot path.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream for worker `idx` (used by the loader
    /// to give every sampling worker its own deterministic stream).
    pub fn fork(&self, idx: u64) -> Rng {
        let mut sm = SplitMix64::new(self.s[0] ^ idx.wrapping_mul(0x9E3779B97F4A7C15));
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift rejection method.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.next_below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller (used for weight init and synthetic
    /// feature generation).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct items from `[0, n)` without replacement.
    ///
    /// Uses Floyd's algorithm for `k << n` (the neighbor-sampler case) and
    /// a partial Fisher–Yates otherwise. Output order is unspecified.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        if k >= n {
            return (0..n).collect();
        }
        if k * 4 <= n {
            // Floyd's: O(k) expected, no O(n) allocation.
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.index(j + 1);
                if out.contains(&t) {
                    out.push(j);
                } else {
                    out.push(t);
                }
            }
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.index(n - i);
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        }
    }

    /// Weighted index sampling by cumulative scan (used by the annealing
    /// temporal strategy). `weights` need not be normalized.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.index(weights.len());
        }
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipf-distributed sampler over `[0, n)` for recommendation-style
/// skewed access patterns (a few hot nodes dominate serving traffic).
///
/// Precomputes the cumulative weights `sum_{i<=k} 1/(i+1)^exponent`
/// once, then draws by inverse-CDF binary search — O(n) setup,
/// O(log n) per sample, fully deterministic given the caller's [`Rng`].
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build a sampler over `n` items with the given skew `exponent`
    /// (0.0 = uniform; ~1.0 = classic Zipf). Panics if `n == 0`.
    pub fn new(n: usize, exponent: f64) -> Self {
        assert!(n > 0, "Zipf over an empty domain");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(exponent);
            cdf.push(acc);
        }
        Self { cdf }
    }

    /// Number of items in the domain.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Draw one index in `[0, n)`; lower indices are hotter.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let total = *self.cdf.last().unwrap();
        let target = rng.f64() * total;
        // First index whose cumulative weight exceeds the target.
        match self.cdf.binary_search_by(|c| c.partial_cmp(&target).unwrap()) {
            Ok(i) => (i + 1).min(self.cdf.len() - 1),
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn differs_across_seeds_and_forks() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
        let base = Rng::new(7);
        let mut f0 = base.fork(0);
        let mut f1 = base.fork(1);
        assert_ne!(f0.next_u64(), f1.next_u64());
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Rng::new(3);
        for n in [1u64, 2, 7, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.next_below(n) < n);
            }
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut r = Rng::new(11);
        for (n, k) in [(100, 5), (100, 50), (10, 10), (10, 20), (1000, 3)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k.min(n));
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), s.len(), "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..57).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..57).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_prefers_heavy_weight() {
        let mut r = Rng::new(13);
        let w = [0.01, 0.01, 10.0, 0.01];
        let mut hits = 0;
        for _ in 0..1000 {
            if r.weighted_index(&w) == 2 {
                hits += 1;
            }
        }
        assert!(hits > 900, "hits={hits}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let zipf = Zipf::new(1000, 1.0);
        let mut r = Rng::new(21);
        let mut counts = vec![0usize; 1000];
        for _ in 0..20_000 {
            let i = zipf.sample(&mut r);
            assert!(i < 1000);
            counts[i] += 1;
        }
        // Rank 0 must dominate and the head must hold most of the mass.
        assert!(counts[0] > counts[10], "head={} rank10={}", counts[0], counts[10]);
        let head: usize = counts[..100].iter().sum();
        assert!(head > 12_000, "head mass {head} of 20000");
        // Exponent 0 degenerates to uniform: no such head concentration.
        let flat = Zipf::new(1000, 0.0);
        let mut counts = vec![0usize; 1000];
        for _ in 0..20_000 {
            counts[flat.sample(&mut r)] += 1;
        }
        let head: usize = counts[..100].iter().sum();
        assert!(head < 4_000, "uniform head mass {head} of 20000");
    }

    #[test]
    fn zipf_deterministic_for_same_seed() {
        let zipf = Zipf::new(64, 0.9);
        let mut a = Rng::new(33);
        let mut b = Rng::new(33);
        for _ in 0..200 {
            assert_eq!(zipf.sample(&mut a), zipf.sample(&mut b));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }
}
