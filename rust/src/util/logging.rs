//! Tiny `log` backend printing to stderr with timestamps.

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::OnceLock;
use std::time::Instant;

static START: OnceLock<Instant> = OnceLock::new();

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = start().elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        // Module path of the log site (falls back to the target, which
        // defaults to the module path anyway for bare `log!` calls).
        let module = record.module_path().unwrap_or_else(|| record.target());
        eprintln!("[{t:9.3}s {lvl} {module}] {}", record.args());
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// The level filter a `PYG2_LOG` value selects (case-insensitive;
/// `off` silences everything; unset or unrecognized → the default,
/// `info`).
pub fn level_from_env(value: Option<&str>) -> LevelFilter {
    match value.map(|v| v.trim().to_ascii_lowercase()).as_deref() {
        Some("off") => LevelFilter::Off,
        Some("error") => LevelFilter::Error,
        Some("warn") => LevelFilter::Warn,
        Some("info") => LevelFilter::Info,
        Some("debug") => LevelFilter::Debug,
        Some("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    }
}

/// Install the logger once; level from `PYG2_LOG` (error|warn|info|debug|trace).
pub fn init() {
    let level = level_from_env(std::env::var("PYG2_LOG").ok().as_deref());
    // Ignore the error if a logger is already set (tests call init repeatedly).
    let _ = log::set_logger(&LOGGER).map(|()| log::set_max_level(level));
    start();
}

#[cfg(test)]
mod tests {
    use log::LevelFilter;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }

    #[test]
    fn env_levels_parse_case_insensitively_with_info_default() {
        for (v, want) in [
            (Some("error"), LevelFilter::Error),
            (Some("WARN"), LevelFilter::Warn),
            (Some("info"), LevelFilter::Info),
            (Some(" Debug "), LevelFilter::Debug),
            (Some("TRACE"), LevelFilter::Trace),
            (Some("off"), LevelFilter::Off),
            (Some("bogus"), LevelFilter::Info),
            (None, LevelFilter::Info),
        ] {
            assert_eq!(super::level_from_env(v), want, "PYG2_LOG={v:?}");
        }
    }
}
