//! Tiny `log` backend printing to stderr with timestamps.

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::OnceLock;
use std::time::Instant;

static START: OnceLock<Instant> = OnceLock::new();

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = start().elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the logger once; level from `PYG2_LOG` (error|warn|info|debug|trace).
pub fn init() {
    let level = match std::env::var("PYG2_LOG").ok().as_deref() {
        Some("error") => LevelFilter::Error,
        Some("warn") => LevelFilter::Warn,
        Some("debug") => LevelFilter::Debug,
        Some("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    // Ignore the error if a logger is already set (tests call init repeatedly).
    let _ = log::set_logger(&LOGGER).map(|()| log::set_max_level(level));
    start();
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
