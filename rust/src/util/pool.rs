//! Thread pool and bounded channels for the data-loading pipeline.
//!
//! `tokio`/`rayon` are unavailable offline; the loader's concurrency model
//! (PyG's DataLoader workers + prefetch queue) maps cleanly onto OS threads
//! plus a bounded MPMC queue, which doubles as the backpressure mechanism:
//! producers block when the queue is full, exactly like a prefetch factor.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Error returned when sending to a closed channel.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError;

/// A bounded multi-producer multi-consumer channel.
///
/// `send` blocks while full (backpressure); `recv` blocks while empty and
/// returns `None` once the channel is closed *and* drained.
pub struct BoundedQueue<T> {
    inner: Mutex<QueueInner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

struct QueueInner<T> {
    q: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Arc<Self> {
        assert!(cap > 0, "queue capacity must be positive");
        Arc::new(Self {
            inner: Mutex::new(QueueInner { q: VecDeque::with_capacity(cap), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap,
        })
    }

    /// Blocking send. Errors if the channel was closed.
    pub fn send(&self, item: T) -> Result<(), SendError> {
        let mut g = self.inner.lock().unwrap();
        while g.q.len() >= self.cap && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return Err(SendError);
        }
        g.q.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking receive; `None` when closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.q.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        let item = g.q.pop_front();
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Close the channel: senders error, receivers drain then get `None`.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Current queue depth (for instrumentation).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A fixed-size worker pool executing boxed jobs.
///
/// Jobs are `FnOnce() + Send`; results flow through caller-owned channels
/// (the loader wires a `BoundedQueue<Batch>` through its jobs).
pub struct ThreadPool {
    job_tx: Arc<BoundedQueue<Job>>,
    handles: Vec<JoinHandle<()>>,
    pending: Arc<AtomicUsize>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl ThreadPool {
    pub fn new(workers: usize) -> Self {
        Self::with_queue_capacity(workers, workers.max(1) * 4)
    }

    pub fn with_queue_capacity(workers: usize, cap: usize) -> Self {
        let workers = workers.max(1);
        let job_tx = BoundedQueue::<Job>::new(cap);
        let pending = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let rx = Arc::clone(&job_tx);
            let pend = Arc::clone(&pending);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("pyg2-worker-{w}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            job();
                            pend.fetch_sub(1, Ordering::Release);
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        Self { job_tx, handles, pending }
    }

    /// Submit a job; blocks if the job queue is full (backpressure).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.pending.fetch_add(1, Ordering::Acquire);
        if self.job_tx.send(Box::new(job)).is_err() {
            self.pending.fetch_sub(1, Ordering::Release);
            panic!("submit on closed pool");
        }
    }

    /// Number of submitted-but-unfinished jobs.
    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::Acquire)
    }

    /// Busy-wait (with yields) until all submitted jobs finished.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            std::thread::yield_now();
        }
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.job_tx.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn queue_fifo_and_close() {
        let q = BoundedQueue::new(4);
        q.send(1).unwrap();
        q.send(2).unwrap();
        assert_eq!(q.recv(), Some(1));
        q.close();
        assert_eq!(q.recv(), Some(2)); // drain after close
        assert_eq!(q.recv(), None);
        assert_eq!(q.send(3), Err(SendError));
    }

    #[test]
    fn queue_blocks_when_full_until_consumed() {
        let q = BoundedQueue::new(1);
        q.send(0u32).unwrap();
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || q2.send(1).unwrap());
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.len(), 1, "producer must be blocked");
        assert_eq!(q.recv(), Some(0));
        t.join().unwrap();
        assert_eq!(q.recv(), Some(1));
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_drop_joins_threads() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop closes + joins
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn mpmc_many_producers_consumers() {
        let q = BoundedQueue::new(8);
        let n_items = 1000;
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..n_items / 4 {
                        q.send(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        let got = Arc::new(AtomicU64::new(0));
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                let g = Arc::clone(&got);
                std::thread::spawn(move || {
                    while q.recv().is_some() {
                        g.fetch_add(1, Ordering::Relaxed);
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(got.load(Ordering::Relaxed), n_items as u64);
    }
}
