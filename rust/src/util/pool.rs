//! Thread pool and bounded channels for the data-loading pipeline.
//!
//! `tokio`/`rayon` are unavailable offline; the loader's concurrency model
//! (PyG's DataLoader workers + prefetch queue) maps cleanly onto OS threads
//! plus a bounded MPMC queue, which doubles as the backpressure mechanism:
//! producers block when the queue is full, exactly like a prefetch factor.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Error returned when sending to a closed channel.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError;

/// Outcome of a timed receive ([`BoundedQueue::recv_deadline`]).
#[derive(Debug, PartialEq, Eq)]
pub enum RecvDeadline<T> {
    /// An item arrived before the deadline.
    Item(T),
    /// The deadline passed with the queue still empty and open.
    TimedOut,
    /// The queue is closed and fully drained.
    Closed,
}

/// Registry handles of an observed queue ([`BoundedQueue::new_observed`]).
struct QueueObs {
    depth: Arc<crate::obs::Gauge>,
    wait_us: Arc<crate::obs::Histogram>,
}

/// A bounded multi-producer multi-consumer channel.
///
/// `send` blocks while full (backpressure); `recv` blocks while empty and
/// returns `None` once the channel is closed *and* drained.
pub struct BoundedQueue<T> {
    inner: Mutex<QueueInner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
    obs: Option<QueueObs>,
}

struct QueueInner<T> {
    q: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Arc<Self> {
        assert!(cap > 0, "queue capacity must be positive");
        Arc::new(Self {
            inner: Mutex::new(QueueInner { q: VecDeque::with_capacity(cap), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap,
            obs: None,
        })
    }

    /// A queue publishing to the metrics registry under `prefix`:
    /// `{prefix}.depth` (gauge, always maintained — one relaxed add per
    /// send/receive) and `{prefix}.wait_us` (histogram of receiver wait
    /// times, only timed while stage tracing is enabled). Generic
    /// queues (e.g. the thread-pool job queue) stay unobserved; the
    /// serve inbox opts in.
    pub fn new_observed(cap: usize, prefix: &str) -> Arc<Self> {
        assert!(cap > 0, "queue capacity must be positive");
        let scope = crate::obs::Scope::new(prefix);
        Arc::new(Self {
            inner: Mutex::new(QueueInner { q: VecDeque::with_capacity(cap), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap,
            obs: Some(QueueObs {
                depth: scope.gauge("depth"),
                wait_us: scope.histogram("wait_us"),
            }),
        })
    }

    /// Start of a receiver-wait measurement (observed queue + tracing on).
    fn wait_clock(&self) -> Option<Instant> {
        match &self.obs {
            Some(_) if crate::obs::enabled() => Some(Instant::now()),
            _ => None,
        }
    }

    /// Close out a successful receive: depth gauge down, wait recorded.
    fn note_recv(&self, started: Option<Instant>) {
        if let Some(obs) = &self.obs {
            obs.depth.sub(1);
            if let Some(t0) = started {
                obs.wait_us.record(t0.elapsed().as_micros() as u64);
            }
        }
    }

    /// Blocking send. Errors if the channel was closed.
    pub fn send(&self, item: T) -> Result<(), SendError> {
        let mut g = self.inner.lock().unwrap();
        while g.q.len() >= self.cap && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return Err(SendError);
        }
        g.q.push_back(item);
        if let Some(obs) = &self.obs {
            obs.depth.add(1);
        }
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking receive; `None` when closed and drained.
    pub fn recv(&self) -> Option<T> {
        let started = self.wait_clock();
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.q.pop_front() {
                self.not_full.notify_one();
                drop(g);
                self.note_recv(started);
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Blocking receive with a deadline: parks on the condvar (no spin)
    /// until an item arrives, the queue closes, or `deadline` passes.
    ///
    /// This is the primitive behind dynamic batching in the inference
    /// server: the batcher waits out its `max_wait` window without
    /// burning a core, unlike the `try_recv` + `yield_now` loop it
    /// replaces.
    pub fn recv_deadline(&self, deadline: Instant) -> RecvDeadline<T> {
        let started = self.wait_clock();
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.q.pop_front() {
                self.not_full.notify_one();
                drop(g);
                self.note_recv(started);
                return RecvDeadline::Item(item);
            }
            if g.closed {
                return RecvDeadline::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return RecvDeadline::TimedOut;
            }
            // Spurious wakeups and races are absorbed by the loop: we
            // re-check queue/closed/deadline on every iteration.
            let (guard, _timeout) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        let item = g.q.pop_front();
        if item.is_some() {
            self.not_full.notify_one();
            drop(g);
            self.note_recv(None);
        }
        item
    }

    /// Close the channel: senders error, receivers drain then get `None`.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Current queue depth (for instrumentation).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A fixed-size worker pool executing boxed jobs.
///
/// Jobs are `FnOnce() + Send`; results flow through caller-owned channels
/// (the loader wires a `BoundedQueue<Batch>` through its jobs), or through
/// the [`TaskHandle`] returned by [`ThreadPool::spawn`] for jobs whose
/// single result is joined later (the async-routing fetch futures of
/// [`crate::dist::AsyncRouter`]).
pub struct ThreadPool {
    job_tx: Arc<BoundedQueue<Job>>,
    handles: Vec<JoinHandle<()>>,
    pending: Arc<AtomicUsize>,
}

/// A join handle for one value produced on a pool worker — the minimal
/// future: `join` blocks until the job has run and yields its result. A
/// job that panicked resumes its panic at `join` (the unwind is caught
/// on the worker, which stays alive) instead of hanging the joiner.
pub struct TaskHandle<T> {
    slot: Arc<(Mutex<Option<std::thread::Result<T>>>, Condvar)>,
}

impl<T: Send + 'static> TaskHandle<T> {
    /// Block until the spawned job finishes and take its result,
    /// resuming the job's panic if it had one.
    pub fn join(self) -> T {
        let (lock, cv) = &*self.slot;
        let mut guard = lock.lock().unwrap();
        loop {
            match guard.take() {
                Some(Ok(v)) => return v,
                Some(Err(payload)) => std::panic::resume_unwind(payload),
                None => guard = cv.wait(guard).unwrap(),
            }
        }
    }

    /// Whether the result is already available (`join` would not block).
    pub fn is_ready(&self) -> bool {
        self.slot.0.lock().unwrap().is_some()
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl ThreadPool {
    pub fn new(workers: usize) -> Self {
        Self::with_queue_capacity(workers, workers.max(1) * 4)
    }

    pub fn with_queue_capacity(workers: usize, cap: usize) -> Self {
        let workers = workers.max(1);
        let job_tx = BoundedQueue::<Job>::new(cap);
        let pending = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let rx = Arc::clone(&job_tx);
            let pend = Arc::clone(&pending);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("pyg2-worker-{w}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            job();
                            pend.fetch_sub(1, Ordering::Release);
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        Self { job_tx, handles, pending }
    }

    /// Submit a job; blocks if the job queue is full (backpressure).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.pending.fetch_add(1, Ordering::Acquire);
        if self.job_tx.send(Box::new(job)).is_err() {
            self.pending.fetch_sub(1, Ordering::Release);
            panic!("submit on closed pool");
        }
    }

    /// Submit a job that produces a value; returns a [`TaskHandle`] that
    /// joins it. Blocks like [`ThreadPool::submit`] when the job queue is
    /// full.
    pub fn spawn<T, F>(&self, f: F) -> TaskHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let slot = Arc::new((Mutex::new(None), Condvar::new()));
        let out = Arc::clone(&slot);
        self.submit(move || {
            // Contain a panicking job so the worker survives and the
            // joiner sees the panic instead of blocking forever.
            let v = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
            let (lock, cv) = &*out;
            *lock.lock().unwrap() = Some(v);
            cv.notify_all();
        });
        TaskHandle { slot }
    }

    /// Number of submitted-but-unfinished jobs.
    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::Acquire)
    }

    /// Busy-wait (with yields) until all submitted jobs finished.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            std::thread::yield_now();
        }
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.job_tx.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn queue_fifo_and_close() {
        let q = BoundedQueue::new(4);
        q.send(1).unwrap();
        q.send(2).unwrap();
        assert_eq!(q.recv(), Some(1));
        q.close();
        assert_eq!(q.recv(), Some(2)); // drain after close
        assert_eq!(q.recv(), None);
        assert_eq!(q.send(3), Err(SendError));
    }

    #[test]
    fn queue_blocks_when_full_until_consumed() {
        let q = BoundedQueue::new(1);
        q.send(0u32).unwrap();
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || q2.send(1).unwrap());
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.len(), 1, "producer must be blocked");
        assert_eq!(q.recv(), Some(0));
        t.join().unwrap();
        assert_eq!(q.recv(), Some(1));
    }

    #[test]
    fn recv_deadline_times_out_without_spinning() {
        let q: Arc<BoundedQueue<u32>> = BoundedQueue::new(4);
        let wait = std::time::Duration::from_millis(40);
        let start = Instant::now();
        let got = q.recv_deadline(start + wait);
        assert_eq!(got, RecvDeadline::TimedOut);
        // The wait is a real blocking park: the full window must elapse
        // (a busy-wait would also satisfy this, but the CPU-time check
        // below distinguishes them on platforms that expose it).
        assert!(start.elapsed() >= wait, "returned before the deadline");
    }

    #[test]
    fn recv_deadline_wakes_on_send_and_close() {
        let q: Arc<BoundedQueue<u32>> = BoundedQueue::new(4);
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            q2.send(9).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(10));
            q2.close();
        });
        let far = Instant::now() + std::time::Duration::from_secs(5);
        assert_eq!(q.recv_deadline(far), RecvDeadline::Item(9));
        assert_eq!(q.recv_deadline(far), RecvDeadline::Closed);
        t.join().unwrap();
    }

    #[test]
    fn recv_deadline_drains_after_close() {
        let q: Arc<BoundedQueue<u32>> = BoundedQueue::new(4);
        q.send(1).unwrap();
        q.close();
        let past = Instant::now();
        // Items still drain even with an already-expired deadline.
        assert_eq!(q.recv_deadline(past), RecvDeadline::Item(1));
        assert_eq!(q.recv_deadline(past), RecvDeadline::Closed);
    }

    #[test]
    fn recv_deadline_idle_wait_uses_no_cpu() {
        // The acceptance check for the busy-wait fix: parking on the
        // condvar for 150ms of wall time must consume (almost) no
        // thread CPU time. The old loop burned the full window.
        let q: Arc<BoundedQueue<u32>> = BoundedQueue::new(4);
        let wall = std::time::Duration::from_millis(150);
        let cpu_before = thread_cpu_time();
        let got = q.recv_deadline(Instant::now() + wall);
        let cpu_spent = thread_cpu_time() - cpu_before;
        assert_eq!(got, RecvDeadline::TimedOut);
        // Generous bound: scheduling noise is fine, spinning (≈150ms) is not.
        assert!(
            cpu_spent < wall.as_secs_f64() * 0.5,
            "idle recv_deadline burned {cpu_spent:.3}s CPU over a {wall:?} wait"
        );
    }

    /// Per-thread CPU seconds via CLOCK_THREAD_CPUTIME_ID (linux targets).
    #[cfg(target_os = "linux")]
    fn thread_cpu_time() -> f64 {
        let mut ts = std::mem::MaybeUninit::<Timespec>::uninit();
        // SAFETY: clock_gettime writes a timespec on success; clockid 3
        // is CLOCK_THREAD_CPUTIME_ID on linux.
        let rc = unsafe { clock_gettime(3, ts.as_mut_ptr()) };
        assert_eq!(rc, 0, "clock_gettime failed");
        let ts = unsafe { ts.assume_init() };
        ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
    }

    #[cfg(not(target_os = "linux"))]
    fn thread_cpu_time() -> f64 {
        0.0 // degrade to a no-op bound off linux; the timeout test still runs
    }

    #[cfg(target_os = "linux")]
    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }

    #[cfg(target_os = "linux")]
    extern "C" {
        fn clock_gettime(clockid: i32, tp: *mut Timespec) -> i32;
    }

    #[test]
    fn observed_queue_tracks_depth() {
        let q = BoundedQueue::new_observed(4, "test.pool.queue");
        // Read through the queue's own handle: the scope may be `#n`-
        // suffixed if a parallel test claimed the prefix first.
        let depth = Arc::clone(&q.obs.as_ref().unwrap().depth);
        q.send(1u32).unwrap();
        q.send(2u32).unwrap();
        assert_eq!(depth.get(), 2);
        assert_eq!(q.recv(), Some(1));
        assert_eq!(q.try_recv(), Some(2));
        assert_eq!(depth.get(), 0);
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_drop_joins_threads() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop closes + joins
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn spawn_joins_results_in_any_order() {
        let pool = ThreadPool::new(3);
        let handles: Vec<_> = (0..20u64)
            .map(|i| pool.spawn(move || i * i))
            .collect();
        // Join in reverse submission order: handles must not require FIFO
        // consumption (the async router joins per-partition fetches in
        // partition order, not completion order).
        for (i, h) in handles.into_iter().enumerate().rev() {
            assert_eq!(h.join(), (i * i) as u64);
        }
    }

    #[test]
    fn spawn_result_becomes_ready() {
        let pool = ThreadPool::new(1);
        let h = pool.spawn(|| 7u32);
        pool.wait_idle();
        assert!(h.is_ready());
        assert_eq!(h.join(), 7);
    }

    #[test]
    fn spawn_panic_propagates_at_join_and_worker_survives() {
        let pool = ThreadPool::new(1);
        let h = pool.spawn(|| -> u32 { panic!("job panic") });
        let joined = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || h.join()));
        assert!(joined.is_err(), "join must resume the job's panic");
        // The worker caught the unwind: the pool still serves jobs.
        assert_eq!(pool.spawn(|| 5u32).join(), 5);
    }

    #[test]
    fn mpmc_many_producers_consumers() {
        let q = BoundedQueue::new(8);
        let n_items = 1000;
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..n_items / 4 {
                        q.send(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        let got = Arc::new(AtomicU64::new(0));
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                let g = Arc::clone(&got);
                std::thread::spawn(move || {
                    while q.recv().is_some() {
                        g.fetch_add(1, Ordering::Relaxed);
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(got.load(Ordering::Relaxed), n_items as u64);
    }
}
