//! Relational Deep Learning (§3.1): relational database → heterogeneous
//! temporal graph → training-table-driven loading → hetero GNN batches
//! for the `rdl_train` artifact (grouped-matmul encoder).

use crate::datasets::relational::{Column, Database};
use crate::error::{Error, Result};
use crate::graph::{EdgeIndex, EdgeType, HeteroGraph};
use crate::loader::{SeedTable, SeedTableBatch};
use crate::runtime::Value;
use crate::storage::TableEncoder;
use std::collections::BTreeMap;

/// Build a heterogeneous temporal graph from a relational database:
/// every table becomes a node type, every FK column an edge type
/// (row -> referenced row), timestamp columns become edge/node times.
/// Features are TensorFrame-encoded and padded to `f_dim`.
pub fn database_to_graph(db: &Database, f_dim: usize) -> Result<HeteroGraph> {
    let mut g = HeteroGraph::new();
    // Node types + encoded features.
    for table in &db.tables {
        let enc = TableEncoder::fit(table);
        if enc.out_dim() > f_dim {
            return Err(Error::Graph(format!(
                "table {} encodes to {} dims > budget {f_dim}",
                table.name,
                enc.out_dim()
            )));
        }
        let x = enc.encode(table, Some(f_dim))?;
        g.add_node_type(&table.name, x)?;
        // Row-level timestamps become node times.
        if let Some(Column::Time(t)) = table.column("time") {
            g.set_node_time(&table.name, t.clone())?;
        }
    }
    // FK columns become edge types (plus the reverse direction, as PyG's
    // `ToUndirected` adds for RDL — without it, 2-hop expansion from the
    // seed entity dead-ends at its fact rows). Both directions carry the
    // fact row's timestamp.
    for table in &db.tables {
        let times = match table.column("time") {
            Some(Column::Time(t)) => Some(t.clone()),
            _ => None,
        };
        for (col_name, col) in &table.columns {
            if let Column::Fk { table: target, rows } = col {
                let src: Vec<u32> = (0..rows.len() as u32).collect();
                let n = rows.len().max(g.num_nodes(target)?);
                let ei = EdgeIndex::new(src.clone(), rows.clone(), n)?;
                let et = EdgeType::new(&table.name, &format!("fk_{col_name}"), target);
                g.add_edge_type(et.clone(), ei)?;
                let rev = EdgeIndex::new(rows.clone(), src, n)?;
                let ret = EdgeType::new(target, &format!("rev_fk_{col_name}"), &table.name);
                g.add_edge_type(ret.clone(), rev)?;
                if let Some(t) = &times {
                    g.set_edge_time(&et, t.clone())?;
                    g.set_edge_time(&ret, t.clone())?;
                }
            }
        }
    }
    Ok(g)
}

/// Build the churn-style training table: one row per user, seed time =
/// horizon, label = future activity.
pub fn build_training_table(db: &Database) -> Result<SeedTable> {
    let labels = crate::datasets::relational::future_activity_labels(db);
    let n = labels.len();
    SeedTable::new(
        "users",
        (0..n as u32).collect(),
        vec![db.horizon; n],
        labels,
    )
}

/// Static shapes of the `rdl_train` artifact (mirrors aot.py `RDL`).
#[derive(Clone, Copy, Debug)]
pub struct RdlShapes {
    pub num_types: usize,
    pub nt_pad: usize,
    pub f_in: usize,
    pub s_pad: usize,
    pub e_pad: usize,
}

impl Default for RdlShapes {
    fn default() -> Self {
        Self { num_types: 4, nt_pad: 256, f_in: 16, s_pad: 64, e_pad: 4096 }
    }
}

/// Pack a hetero seed-table batch into `rdl_train` inputs:
/// `(x_typed [T, NT, F], row, col, ew, labels, seed_mask)`.
///
/// Flat node space is type-major (`flat = t * NT + i`) with the **seed
/// type first**, so the model's `h[:s_pad]` slice hits the seed rows.
pub fn pack_rdl_batch(
    graph: &HeteroGraph,
    batch: &SeedTableBatch,
    shapes: &RdlShapes,
) -> Result<Vec<Value>> {
    let seed_type = &batch.sub.seed_type;
    // Type order: seed type first, the rest sorted.
    let mut type_order: Vec<String> = vec![seed_type.clone()];
    for nt in graph.node_types() {
        if nt != seed_type {
            type_order.push(nt.to_string());
        }
    }
    if type_order.len() != shapes.num_types {
        return Err(Error::Shape(format!(
            "graph has {} node types; artifact expects {}",
            type_order.len(),
            shapes.num_types
        )));
    }
    let type_idx: BTreeMap<&str, usize> = type_order
        .iter()
        .enumerate()
        .map(|(i, n)| (n.as_str(), i))
        .collect();

    // Features, type-bucketed.
    let mut x = vec![0.0f32; shapes.num_types * shapes.nt_pad * shapes.f_in];
    for (nt, nodes) in &batch.sub.nodes {
        let t = type_idx[nt.as_str()];
        if nodes.len() > shapes.nt_pad {
            return Err(Error::Shape(format!(
                "{nt}: {} nodes exceed NT_pad {}",
                nodes.len(),
                shapes.nt_pad
            )));
        }
        let store = graph.node_store(nt)?;
        if store.x.cols() != shapes.f_in {
            return Err(Error::Shape(format!(
                "{nt}: feature dim {} != {}",
                store.x.cols(),
                shapes.f_in
            )));
        }
        for (i, &global) in nodes.iter().enumerate() {
            let off = (t * shapes.nt_pad + i) * shapes.f_in;
            x[off..off + shapes.f_in].copy_from_slice(store.x.row(global as usize));
        }
    }

    // Edges flattened over the typed space, all edge types merged.
    let mut row = vec![0i32; shapes.e_pad];
    let mut col = vec![0i32; shapes.e_pad];
    let mut ew = vec![0.0f32; shapes.e_pad];
    let mut in_deg: BTreeMap<i32, u32> = BTreeMap::new();
    let mut k = 0usize;
    for (et, edges) in &batch.sub.edges {
        let ts = type_idx[et.src.as_str()] as i32;
        let td = type_idx[et.dst.as_str()] as i32;
        for (&r, &c) in edges.row.iter().zip(&edges.col) {
            if k >= shapes.e_pad {
                return Err(Error::Shape(format!("batch exceeds e_pad {}", shapes.e_pad)));
            }
            row[k] = ts * shapes.nt_pad as i32 + r as i32;
            col[k] = td * shapes.nt_pad as i32 + c as i32;
            *in_deg.entry(col[k]).or_insert(0) += 1;
            k += 1;
        }
    }
    let real_edges = k;
    for k in 0..real_edges {
        ew[k] = 1.0 / in_deg[&col[k]].max(1) as f32;
    }

    // Seed labels.
    if batch.seeds.len() > shapes.s_pad {
        return Err(Error::Shape(format!(
            "{} seeds exceed s_pad {}",
            batch.seeds.len(),
            shapes.s_pad
        )));
    }
    let mut labels = vec![-1i32; shapes.s_pad];
    let mut seed_mask = vec![0.0f32; shapes.s_pad];
    for (i, &l) in batch.labels.iter().enumerate() {
        labels[i] = l as i32;
        seed_mask[i] = 1.0;
    }

    Ok(vec![
        Value::F32 {
            shape: vec![shapes.num_types, shapes.nt_pad, shapes.f_in],
            data: x,
        },
        Value::I32 { shape: vec![shapes.e_pad], data: row },
        Value::I32 { shape: vec![shapes.e_pad], data: col },
        Value::F32 { shape: vec![shapes.e_pad], data: ew },
        Value::I32 { shape: vec![shapes.s_pad], data: labels },
        Value::F32 { shape: vec![shapes.s_pad], data: seed_mask },
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::relational::{self, RelationalConfig};

    #[test]
    fn database_roundtrips_to_hetero_graph() {
        let db = relational::generate(&RelationalConfig::default()).unwrap();
        let g = database_to_graph(&db, 16).unwrap();
        assert_eq!(g.num_node_types(), 4);
        // transactions + reviews each have 2 FKs -> 4 forward + 4 reverse.
        assert_eq!(g.num_edge_types(), 8);
        assert_eq!(g.num_nodes("users").unwrap(), 500);
        // transactions edges are timestamped.
        let et = EdgeType::new("transactions", "fk_user", "users");
        assert!(g.edge_store(&et).unwrap().time.is_some());
        let ret = EdgeType::new("users", "rev_fk_user", "transactions");
        assert!(g.edge_store(&ret).unwrap().time.is_some());
    }

    #[test]
    fn training_table_aligns_with_users() {
        let db = relational::generate(&RelationalConfig::default()).unwrap();
        let t = build_training_table(&db).unwrap();
        assert_eq!(t.len(), 500);
        assert!(t.times.iter().all(|&x| x == db.horizon));
        assert_eq!(t.node_type, "users");
    }

    #[test]
    fn feature_budget_enforced() {
        let db = relational::generate(&RelationalConfig::default()).unwrap();
        assert!(database_to_graph(&db, 2).is_err());
    }
}
