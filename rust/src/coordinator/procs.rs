//! True multi-process ranks over a shared bundle directory (ROADMAP
//! item 2): `run_parent` launches N `pyg2 dist-worker` OS processes,
//! each mounting the shared bundle read-only with its own cache budget
//! and fetching foreign feature rows from its peers over the
//! unix-socket [`crate::dist::SocketTransport`] instead of its local
//! shard replicas. The parent coordinates the run over a length-prefixed
//! control socket, collects per-rank batch digests and traffic rows,
//! aggregates them into the same [`crate::dist::TrafficMatrix`] the
//! sequential [`super::multi_rank_epoch_mounted`] simulation reports,
//! and measures real wall-clock overlap.
//!
//! Lifecycle (all frames are 4-byte-LE length-prefixed JSON on
//! `{sock_dir}/ctl.sock`; the feature-row data plane runs on binary
//! frames over `{sock_dir}/peer{rank}.sock`, see
//! [`crate::dist::transport`]):
//!
//! 1. parent binds the control socket, spawns the workers;
//! 2. each worker mounts the bundle, binds its peer socket, connects to
//!    the control socket and sends `{"type":"hello","rank":R}`;
//! 3. once every rank checked in the parent fans out `{"type":"go"}`
//!    and starts the wall clock — workers run their epochs truly
//!    concurrently, serving each other's row fetches as they go;
//! 4. each worker reports `{"type":"report",...}` (batch digests,
//!    per-partition traffic, epoch seconds) or `{"type":"error",...}`;
//! 5. the parent replies `{"type":"bye"}`, the workers tear down their
//!    peer servers and exit, and the parent merges their telemetry.
//!
//! Crash semantics: every parent-side wait polls the children — a
//! worker dying mid-epoch (or never checking in) surfaces as a typed
//! [`Error::Worker`] at the parent within the deadline, never a hang;
//! the remaining workers are killed and reaped before `run_parent`
//! returns. On the data plane a dead peer shows up as a broken socket,
//! which the victim worker reports as its own typed error.

use super::{record_rank_epoch, DistOptions};
use crate::dist::transport::write_frame;
use crate::dist::{PeerServer, SocketTransport, TrafficMatrix, Transport};
use crate::error::{Error, Result};
use crate::loader::{Batch, HeteroBatch, HeteroLoaderConfig, LoaderConfig};
use crate::util::json::{self, Json};
use std::io::Read;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Control-plane socket name inside the shared socket directory.
pub const CTL_SOCK: &str = "ctl.sock";

// --- batch digests ------------------------------------------------------

/// FNV-1a 64 accumulator (same polynomial as the persist checksums).
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Self(0xcbf29ce484222325)
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    fn u32s(&mut self, vals: &[u32]) {
        for &v in vals {
            self.write(&v.to_le_bytes());
        }
    }
}

/// Order-sensitive content digest of one homogeneous batch: sampled
/// node ids, feature bytes, padded edge index, edge weights and labels.
/// Two pipelines that produce the same digest stream produced the same
/// batches — how a real multi-process run is pinned against the
/// sequential simulation.
pub fn batch_digest(b: &Batch) -> u64 {
    let mut h = Fnv::new();
    h.u32s(&b.sub.nodes);
    for &v in b.x.data() {
        h.write(&v.to_bits().to_le_bytes());
    }
    for &v in &b.row {
        h.write(&v.to_le_bytes());
    }
    for &v in &b.col {
        h.write(&v.to_le_bytes());
    }
    for &v in &b.ew {
        h.write(&v.to_bits().to_le_bytes());
    }
    for &v in &b.labels {
        h.write(&v.to_le_bytes());
    }
    h.0
}

/// [`batch_digest`] for typed batches: per-type node ids and feature
/// bytes, per-edge-type COO columns, seed labels.
pub fn hetero_batch_digest(b: &HeteroBatch) -> u64 {
    let mut h = Fnv::new();
    for (nt, nodes) in &b.sub.nodes {
        h.write(nt.as_bytes());
        h.u32s(nodes);
        if let Some(x) = b.x.get(nt) {
            for &v in x.data() {
                h.write(&v.to_bits().to_le_bytes());
            }
        }
    }
    for (et, e) in &b.sub.edges {
        h.write(et.key().as_bytes());
        h.u32s(&e.row);
        h.u32s(&e.col);
        h.u32s(&e.edge_ids);
    }
    if let Some(labels) = &b.labels {
        for &l in labels {
            h.write(&l.to_le_bytes());
        }
    }
    h.0
}

// --- control-plane plumbing ---------------------------------------------

fn send_json(stream: &mut UnixStream, msg: &Json) -> Result<()> {
    write_frame(stream, msg.to_string().as_bytes())
}

/// Fill `buf` from the stream, tolerating read timeouts: every timeout
/// re-checks the deadline and the caller's liveness probe (child
/// processes on the parent, nothing on the worker), so a dead
/// counterpart becomes a typed error instead of a hang. The stream must
/// have a short read timeout installed.
fn fill_deadline(
    stream: &mut UnixStream,
    buf: &mut [u8],
    deadline: Instant,
    check: &mut dyn FnMut() -> Result<()>,
) -> Result<()> {
    let mut filled = 0usize;
    while filled < buf.len() {
        if Instant::now() >= deadline {
            return Err(Error::Worker("control channel deadline exceeded".into()));
        }
        check()?;
        match stream.read(&mut buf[filled..]) {
            Ok(0) => return Err(Error::Worker("control channel closed".into())),
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

fn read_json_frame(
    stream: &mut UnixStream,
    deadline: Instant,
    check: &mut dyn FnMut() -> Result<()>,
) -> Result<Json> {
    let mut len = [0u8; 4];
    fill_deadline(stream, &mut len, deadline, check)?;
    let n = u32::from_le_bytes(len);
    if n > crate::dist::transport::MAX_FRAME {
        return Err(Error::Worker(format!("oversized control frame ({n} bytes)")));
    }
    let mut buf = vec![0u8; n as usize];
    fill_deadline(stream, &mut buf, deadline, check)?;
    let text = String::from_utf8(buf)
        .map_err(|_| Error::Worker("non-utf8 control frame".into()))?;
    json::parse(&text).map_err(|e| Error::Worker(format!("bad control frame: {e}")))
}

fn msg_type(msg: &Json) -> Option<&str> {
    msg.get("type").and_then(|j| j.as_str())
}

// --- worker side --------------------------------------------------------

/// Configuration of one `pyg2 dist-worker` rank.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    pub rank: u32,
    pub world: usize,
    /// Directory holding the control and peer sockets.
    pub sock_dir: PathBuf,
    pub epochs: u64,
    pub batch_size: usize,
    pub num_workers: usize,
    /// Seed node type for typed bundles (defaults to the manifest's
    /// first type).
    pub seed_type: Option<String>,
    pub opts: DistOptions,
    pub lru: crate::persist::LruConfig,
    /// Deadline for every control-plane wait and peer dial.
    pub deadline: Duration,
    /// Crash-test hook: exit abruptly after this many batches.
    pub fail_after: Option<usize>,
}

enum RankLoader {
    Homo(crate::dist::DistNeighborLoader),
    Hetero(crate::dist::HeteroDistNeighborLoader),
}

/// Seeds a rank owns: the node ids `assignment` maps to it — the same
/// formula [`super::multi_rank_epoch_mounted`] uses, so a worker's
/// batch stream reproduces its simulated rank seed for seed.
fn owned_seeds(assignment: &[u32], rank: u32) -> Vec<u32> {
    assignment
        .iter()
        .enumerate()
        .filter(|(_, &a)| a == rank)
        .map(|(v, _)| v as u32)
        .collect()
}

fn connect_ctl(sock_dir: &Path, deadline: Duration) -> Result<UnixStream> {
    let path = sock_dir.join(CTL_SOCK);
    let by = Instant::now() + deadline;
    loop {
        match UnixStream::connect(&path) {
            Ok(s) => {
                s.set_read_timeout(Some(Duration::from_millis(100)))?;
                s.set_write_timeout(Some(deadline))?;
                return Ok(s);
            }
            Err(e) => {
                if Instant::now() >= by {
                    return Err(Error::Worker(format!(
                        "control socket {} unreachable: {e}",
                        path.display()
                    )));
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

/// One worker rank's whole life: mount the bundle with a socket
/// transport on the remote feature path, serve peers, run the epochs,
/// report, and tear down on `bye`. Any failure is reported to the
/// parent in-band (best effort) before it becomes this process's error
/// exit.
pub fn run_worker(bundle: &crate::persist::Bundle, wc: &WorkerConfig) -> Result<()> {
    if wc.world == 0 || wc.rank as usize >= wc.world {
        return Err(Error::Config(format!(
            "rank {} outside world of {}",
            wc.rank, wc.world
        )));
    }
    // Tag this process's telemetry so merged metrics self-identify.
    crate::obs::gauge("dist.worker.rank").set(wc.rank as i64);
    let mut ctl = connect_ctl(&wc.sock_dir, wc.deadline)?;
    match run_worker_inner(bundle, wc, &mut ctl) {
        Ok(()) => Ok(()),
        Err(e) => {
            let _ = send_json(
                &mut ctl,
                &Json::obj(vec![
                    ("type", Json::str("error")),
                    ("rank", Json::num(wc.rank as f64)),
                    ("message", Json::str(e.to_string())),
                ]),
            );
            Err(e)
        }
    }
}

fn run_worker_inner(
    bundle: &crate::persist::Bundle,
    wc: &WorkerConfig,
    ctl: &mut UnixStream,
) -> Result<()> {
    let transport = Arc::new(SocketTransport::new(&wc.sock_dir, wc.world, wc.deadline));
    let dyn_transport = Arc::clone(&transport) as Arc<dyn Transport>;
    let loader = if bundle.is_typed() {
        let seed_type = match &wc.seed_type {
            Some(st) => st.clone(),
            None => bundle.manifest().node_types[0].name.clone(),
        };
        let seeds = owned_seeds(&bundle.load_assignment(&seed_type)?, wc.rank);
        let cfg = HeteroLoaderConfig {
            batch_size: wc.batch_size,
            num_workers: wc.num_workers,
            ..Default::default()
        };
        RankLoader::Hetero(super::hetero_mounted_loader_with_transport(
            bundle,
            wc.rank,
            &seed_type,
            seeds,
            cfg,
            wc.opts,
            wc.lru,
            Some(dyn_transport),
        )?)
    } else {
        let assignment = bundle.load_assignment(crate::storage::DEFAULT_GROUP)?;
        let seeds = owned_seeds(&assignment, wc.rank);
        let cfg = LoaderConfig {
            batch_size: wc.batch_size,
            num_workers: wc.num_workers,
            ..Default::default()
        };
        RankLoader::Homo(super::mounted_loader_with_transport(
            bundle,
            wc.rank,
            seeds,
            cfg,
            wc.opts,
            wc.lru,
            Some(dyn_transport),
        )?)
    };
    // Serve peers from this worker's own mounted store; the server must
    // be up before any peer starts its epoch, which the hello → go
    // barrier below guarantees.
    let fs = match &loader {
        RankLoader::Homo(l) => Arc::clone(l.features()),
        RankLoader::Hetero(l) => Arc::clone(l.features()),
    };
    let mut server = PeerServer::spawn(
        SocketTransport::peer_path(&wc.sock_dir, wc.rank as usize),
        fs,
    )?;

    send_json(
        ctl,
        &Json::obj(vec![
            ("type", Json::str("hello")),
            ("rank", Json::num(wc.rank as f64)),
        ]),
    )?;
    let deadline = Instant::now() + wc.deadline;
    let go = read_json_frame(ctl, deadline, &mut || Ok(()))?;
    if msg_type(&go) != Some("go") {
        return Err(Error::Worker(format!("expected go, got {}", go.to_string())));
    }

    let mut digests: Vec<u64> = Vec::new();
    let mut batches = 0usize;
    let mut sampled_nodes = 0usize;
    let t0 = Instant::now();
    match &loader {
        RankLoader::Homo(l) => {
            for epoch in 0..wc.epochs {
                for batch in l.iter_epoch(epoch) {
                    let b = batch?;
                    batches += 1;
                    sampled_nodes += b.num_real_nodes();
                    digests.push(batch_digest(&b));
                    if wc.fail_after == Some(batches) {
                        // Crash test: die abruptly mid-epoch, no report.
                        std::process::exit(17);
                    }
                }
            }
        }
        RankLoader::Hetero(l) => {
            for epoch in 0..wc.epochs {
                for batch in l.iter_epoch(epoch) {
                    let b = batch?;
                    batches += 1;
                    sampled_nodes += b.total_nodes();
                    digests.push(hetero_batch_digest(&b));
                    if wc.fail_after == Some(batches) {
                        std::process::exit(17);
                    }
                }
            }
        }
    }
    let epoch_secs = t0.elapsed().as_secs_f64();
    record_rank_epoch(wc.rank, epoch_secs);

    let traffic = match &loader {
        RankLoader::Homo(l) => l.graph().router().traffic_by_partition(),
        RankLoader::Hetero(l) => l.graph().typed_router().traffic_by_partition(),
    };
    send_json(
        ctl,
        &Json::obj(vec![
            ("type", Json::str("report")),
            ("rank", Json::num(wc.rank as f64)),
            ("batches", Json::num(batches as f64)),
            ("sampled_nodes", Json::num(sampled_nodes as f64)),
            ("epoch_secs", Json::num(epoch_secs)),
            (
                "msgs",
                Json::Arr(traffic.msgs.iter().map(|&m| Json::num(m as f64)).collect()),
            ),
            (
                "rows",
                Json::Arr(traffic.rows.iter().map(|&r| Json::num(r as f64)).collect()),
            ),
            (
                // u64 digests do not fit a JSON f64 exactly: hex strings.
                "digests",
                Json::Arr(digests.iter().map(|d| Json::str(format!("{d:016x}"))).collect()),
            ),
        ]),
    )?;

    // Keep serving peers until every rank reported and the parent says
    // bye — a fast rank tearing down early would break its peers'
    // remaining fetches.
    let bye = read_json_frame(ctl, Instant::now() + wc.deadline, &mut || Ok(()))?;
    if msg_type(&bye) != Some("bye") {
        return Err(Error::Worker(format!("expected bye, got {}", bye.to_string())));
    }
    transport.disconnect();
    drop(loader);
    server.shutdown();
    Ok(())
}

// --- parent side --------------------------------------------------------

/// Configuration of the `pyg2 dist --procs N` launcher.
#[derive(Clone, Debug)]
pub struct DistProcsConfig {
    /// The `pyg2` binary to spawn workers from (usually
    /// `std::env::current_exe()`).
    pub bin: PathBuf,
    /// Bundle directory every worker mounts read-only.
    pub mount: PathBuf,
    /// Number of worker processes (the world size).
    pub procs: usize,
    /// Flags forwarded verbatim to every worker (loader and mount
    /// knobs).
    pub forward: Vec<String>,
    /// Whole-run deadline: handshake, epochs, reports and teardown must
    /// all land inside it.
    pub deadline: Duration,
    /// The parent's own `--metrics-out` path, if any: worker telemetry
    /// is merged into `<path>.workers.jsonl` next to it.
    pub metrics_out: Option<PathBuf>,
}

/// Result of a real multi-process run, shaped to compare directly
/// against [`super::MountedMultiRankReport`].
#[derive(Debug)]
pub struct DistProcsReport {
    pub matrix: TrafficMatrix,
    /// Per-rank batch digest streams ([`batch_digest`]).
    pub digests: Vec<Vec<u64>>,
    /// Per-rank epoch wall-clock, measured concurrently.
    pub rank_seconds: Vec<f64>,
    pub batches: usize,
    pub sampled_nodes: usize,
    /// Parent wall-clock from `go` to the last report.
    pub wall_seconds: f64,
    /// Merged per-worker telemetry file, when the parent exports
    /// metrics.
    pub merged_metrics: Option<PathBuf>,
}

impl DistProcsReport {
    /// Measured overlap factor: sum of per-rank epoch seconds over the
    /// parallel wall-clock. 1.0 means fully sequential; `procs` means
    /// perfectly overlapped ranks.
    pub fn overlap(&self) -> f64 {
        let total: f64 = self.rank_seconds.iter().sum();
        if self.wall_seconds > 0.0 {
            total / self.wall_seconds
        } else {
            0.0
        }
    }

    /// Min/max/mean of [`DistProcsReport::rank_seconds`].
    pub fn skew(&self) -> super::RankSkew {
        super::RankSkew::from_seconds(&self.rank_seconds)
    }
}

/// A socket directory no concurrent launcher in this process (or any
/// other) collides with; unix socket paths are length-limited, so it
/// lives directly under the system temp dir.
fn fresh_sock_dir() -> Result<PathBuf> {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "pyg2_dist_{}_{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

/// Fail with a typed error if any worker process has already exited —
/// the liveness probe every parent-side wait polls, so a killed worker
/// surfaces within one poll interval instead of hanging the run.
fn check_children(children: &mut [Child]) -> Result<()> {
    for (rank, c) in children.iter_mut().enumerate() {
        if let Some(status) = c.try_wait()? {
            return Err(Error::Worker(format!(
                "worker {rank} exited prematurely ({status})"
            )));
        }
    }
    Ok(())
}

/// Launch `procs` worker processes over the shared bundle, coordinate
/// the epoch, and aggregate their reports. See the module docs for the
/// lifecycle; on any failure every surviving worker is killed and
/// reaped before the error returns.
pub fn run_parent(pc: &DistProcsConfig) -> Result<DistProcsReport> {
    if pc.procs == 0 {
        return Err(Error::Config("--procs must be at least 1".into()));
    }
    let bundle = crate::persist::Bundle::open(&pc.mount)?;
    let parts = bundle.num_parts();
    drop(bundle);

    let sock_dir = fresh_sock_dir()?;
    let ctl_path = sock_dir.join(CTL_SOCK);
    let listener = UnixListener::bind(&ctl_path)
        .map_err(|e| Error::Worker(format!("bind {}: {e}", ctl_path.display())))?;
    listener.set_nonblocking(true)?;

    let mut children: Vec<Child> = Vec::new();
    let result = match spawn_workers(pc, &sock_dir, &mut children) {
        Ok(()) => parent_loop(pc, parts, &sock_dir, &listener, &mut children),
        Err(e) => Err(e),
    };
    // Whatever happened, leave no processes and no socket dir behind
    // (worker metrics were already merged out by the success path).
    for c in &mut children {
        let _ = c.kill();
    }
    for c in &mut children {
        let _ = c.wait();
    }
    let _ = std::fs::remove_dir_all(&sock_dir);
    result
}

fn spawn_workers(
    pc: &DistProcsConfig,
    sock_dir: &Path,
    children: &mut Vec<Child>,
) -> Result<()> {
    for rank in 0..pc.procs {
        let metrics = sock_dir.join(format!("rank{rank}.metrics.jsonl"));
        let child = Command::new(&pc.bin)
            .arg("dist-worker")
            .arg(format!("--rank={rank}"))
            .arg(format!("--world={}", pc.procs))
            .arg(format!("--mount={}", pc.mount.display()))
            .arg(format!("--sock-dir={}", sock_dir.display()))
            .arg(format!("--metrics-out={}", metrics.display()))
            .args(&pc.forward)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .map_err(|e| Error::Worker(format!("spawn worker {rank}: {e}")))?;
        children.push(child);
    }
    Ok(())
}

fn parent_loop(
    pc: &DistProcsConfig,
    parts: usize,
    sock_dir: &Path,
    listener: &UnixListener,
    children: &mut Vec<Child>,
) -> Result<DistProcsReport> {
    let world = pc.procs;
    let deadline = Instant::now() + pc.deadline;

    // Hello barrier: every rank checks in before anyone runs.
    let mut pending: Vec<Option<UnixStream>> = (0..world).map(|_| None).collect();
    let mut connected = 0usize;
    while connected < world {
        if Instant::now() >= deadline {
            return Err(Error::Worker(format!(
                "only {connected}/{world} workers checked in before the deadline"
            )));
        }
        check_children(children)?;
        match listener.accept() {
            Ok((mut s, _)) => {
                s.set_nonblocking(false)?;
                s.set_read_timeout(Some(Duration::from_millis(100)))?;
                s.set_write_timeout(Some(pc.deadline))?;
                let hello = read_json_frame(&mut s, deadline, &mut || check_children(children))?;
                if msg_type(&hello) != Some("hello") {
                    return Err(Error::Worker(format!(
                        "expected hello, got {}",
                        hello.to_string()
                    )));
                }
                let rank = hello
                    .get("rank")
                    .and_then(|j| j.as_usize())
                    .filter(|&r| r < world)
                    .ok_or_else(|| Error::Worker("hello with a bad rank".into()))?;
                if pending[rank].replace(s).is_some() {
                    return Err(Error::Worker(format!("rank {rank} checked in twice")));
                }
                connected += 1;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    let mut streams: Vec<UnixStream> =
        pending.into_iter().map(|s| s.expect("barrier complete")).collect();

    // Go: the epoch starts now, on every rank at once.
    let go = Json::obj(vec![("type", Json::str("go"))]);
    for s in &mut streams {
        send_json(s, &go)?;
    }
    let t0 = Instant::now();

    // Collect every rank's report (arrival order does not matter — a
    // later rank's report just waits buffered in its socket).
    let mut matrix = TrafficMatrix::new(world, parts);
    let mut digests = Vec::with_capacity(world);
    let mut rank_seconds = Vec::with_capacity(world);
    let mut batches = 0usize;
    let mut sampled_nodes = 0usize;
    for (rank, stream) in streams.iter_mut().enumerate() {
        let msg = read_json_frame(stream, deadline, &mut || check_children(children))?;
        match msg_type(&msg) {
            Some("report") => {}
            Some("error") => {
                let m = msg
                    .get("message")
                    .and_then(|j| j.as_str())
                    .unwrap_or("unknown failure");
                return Err(Error::Worker(format!("worker {rank}: {m}")));
            }
            _ => {
                return Err(Error::Worker(format!(
                    "worker {rank}: unexpected control frame {}",
                    msg.to_string()
                )))
            }
        }
        batches += msg.get("batches").and_then(|j| j.as_usize()).unwrap_or(0);
        sampled_nodes += msg
            .get("sampled_nodes")
            .and_then(|j| j.as_usize())
            .unwrap_or(0);
        let secs = msg.get("epoch_secs").and_then(|j| j.as_f64()).unwrap_or(0.0);
        record_rank_epoch(rank as u32, secs);
        rank_seconds.push(secs);
        let traffic = crate::dist::PartitionTraffic {
            local_rank: rank as u32,
            msgs: json_u64s(&msg, "msgs")?,
            rows: json_u64s(&msg, "rows")?,
        };
        matrix.set_rank(rank, &traffic)?;
        let mut rank_digests = Vec::new();
        for d in msg
            .get("digests")
            .and_then(|j| j.as_arr())
            .unwrap_or(&[])
        {
            let hex = d
                .as_str()
                .ok_or_else(|| Error::Worker(format!("worker {rank}: non-string digest")))?;
            rank_digests.push(
                u64::from_str_radix(hex, 16)
                    .map_err(|_| Error::Worker(format!("worker {rank}: bad digest {hex}")))?,
            );
        }
        digests.push(rank_digests);
    }
    let wall_seconds = t0.elapsed().as_secs_f64();

    // Bye: workers tear down their peer servers and exit.
    let bye = Json::obj(vec![("type", Json::str("bye"))]);
    for s in &mut streams {
        let _ = send_json(s, &bye);
    }
    let mut waiting: Vec<usize> = (0..world).collect();
    while !waiting.is_empty() && Instant::now() < deadline {
        waiting.retain(|&r| !matches!(children[r].try_wait(), Ok(Some(_))));
        if !waiting.is_empty() {
            std::thread::sleep(Duration::from_millis(10));
        }
    }
    // Stragglers are killed by run_parent's cleanup; the run itself
    // succeeded once every report landed.

    let merged_metrics = match &pc.metrics_out {
        Some(out) => Some(merge_worker_metrics(out, sock_dir, world)?),
        None => None,
    };
    Ok(DistProcsReport {
        matrix,
        digests,
        rank_seconds,
        batches,
        sampled_nodes,
        wall_seconds,
        merged_metrics,
    })
}

fn json_u64s(msg: &Json, field: &str) -> Result<Vec<u64>> {
    msg.get(field)
        .and_then(|j| j.as_arr())
        .map(|arr| {
            arr.iter()
                .map(|j| j.as_f64().unwrap_or(0.0) as u64)
                .collect()
        })
        .ok_or_else(|| Error::Worker(format!("report missing {field}")))
}

/// Concatenate every worker's JSONL telemetry into one
/// `<metrics_out>.workers.jsonl` file (each line is a complete snapshot
/// record tagged with its rank via the `dist.worker.rank` gauge, so the
/// merged file passes `pyg2 obs-check`).
fn merge_worker_metrics(metrics_out: &Path, sock_dir: &Path, world: usize) -> Result<PathBuf> {
    use std::io::Write;
    let merged = PathBuf::from(format!("{}.workers.jsonl", metrics_out.display()));
    let mut f = std::fs::File::create(&merged)?;
    for rank in 0..world {
        let path = sock_dir.join(format!("rank{rank}.metrics.jsonl"));
        if let Ok(text) = std::fs::read_to_string(&path) {
            for line in text.lines() {
                if !line.trim().is_empty() {
                    writeln!(f, "{line}")?;
                }
            }
        }
    }
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loader::ShapeBucket;
    use crate::sampler::SampledSubgraph;
    use crate::storage::InMemoryFeatureStore;
    use crate::tensor::Tensor;

    /// A 1-seed, 1-hop batch over the given node ids (all < 4), backed
    /// by a 4-row feature store with distinct rows.
    fn tiny_batch(nodes: Vec<u32>) -> Batch {
        let n = nodes.len();
        let sub = SampledSubgraph {
            nodes,
            row: vec![1],
            col: vec![0],
            edge_ids: vec![0],
            num_seeds: 1,
            node_offsets: vec![1, n],
            edge_offsets: vec![1],
            ..Default::default()
        };
        let x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let store = InMemoryFeatureStore::from_tensor(Tensor::new(vec![4, 2], x).unwrap());
        let bucket = ShapeBucket::for_sampling(1, &[3]);
        Batch::assemble(sub, &store, &crate::storage::FeatureKey::default_x(), None, &bucket)
            .unwrap()
    }

    #[test]
    fn batch_digest_is_content_sensitive() {
        let a = batch_digest(&tiny_batch(vec![0, 1, 2]));
        let b = batch_digest(&tiny_batch(vec![0, 1, 2]));
        let c = batch_digest(&tiny_batch(vec![0, 2, 1]));
        assert_eq!(a, b, "same content, same digest");
        assert_ne!(a, c, "different node order, different digest");
    }

    #[test]
    fn owned_seeds_matches_simulation_formula() {
        let assignment = vec![0u32, 1, 0, 2, 1, 0];
        assert_eq!(owned_seeds(&assignment, 0), vec![0, 2, 5]);
        assert_eq!(owned_seeds(&assignment, 1), vec![1, 4]);
        assert_eq!(owned_seeds(&assignment, 2), vec![3]);
        assert!(owned_seeds(&assignment, 3).is_empty());
    }

    #[test]
    fn parent_rejects_zero_procs_and_bad_mount() {
        let cfg = DistProcsConfig {
            bin: PathBuf::from("/bin/false"),
            mount: PathBuf::from("/nonexistent/bundle"),
            procs: 0,
            forward: Vec::new(),
            deadline: Duration::from_secs(1),
            metrics_out: None,
        };
        assert!(matches!(run_parent(&cfg), Err(Error::Config(_))));
        let cfg = DistProcsConfig { procs: 2, ..cfg };
        assert!(run_parent(&cfg).is_err(), "bad mount dir must error early");
    }

    #[test]
    fn dead_children_fail_the_liveness_probe() {
        let mut children = vec![Command::new("/bin/true")
            .stdout(Stdio::null())
            .spawn()
            .unwrap()];
        // /bin/true exits immediately; the probe must notice.
        std::thread::sleep(Duration::from_millis(50));
        match check_children(&mut children) {
            Err(Error::Worker(m)) => assert!(m.contains("exited prematurely")),
            other => panic!("expected worker error, got {other:?}"),
        }
    }
}
