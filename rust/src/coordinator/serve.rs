//! Inference serving: a request router with dynamic batching.
//!
//! The deployment half of the blueprint (TorchScript/serving in §2.1):
//! clients submit single-node classification requests; the server
//! accumulates them into a batch until `max_batch` seeds or `max_wait`
//! elapses (whichever first), runs the batch through the model, and
//! routes per-seed predictions back to their callers. The batching
//! policy is the standard dynamic-batching tradeoff (throughput vs tail
//! latency) of GNN serving systems.
//!
//! Two backends share the serve loop:
//!
//! * [`InferenceServer::spawn`] — the compiled inference HLO over AOT
//!   artifacts (each server thread owns its own `Engine`; PJRT clients
//!   are not `Send`).
//! * [`InferenceServer::spawn_model`] — the pure-Rust
//!   [`NodeClassifier`], which needs no artifacts and therefore runs in
//!   CI and the offline sandbox. The model path samples each seed's
//!   neighborhood with `batch_seed = node id`, so a node's prediction is
//!   a pure function of the node — independent of batch composition,
//!   worker count, or store backing. The distributed server
//!   (`serve_dist`) relies on exactly this property for its
//!   prediction-identity guarantee.
//!
//! The admission queue is a bounded MPMC channel; the batching loop
//! parks in [`BoundedQueue::recv_deadline`] (condvar wait, not a spin
//! loop), so an idle server burns no CPU. Shutdown closes the inbox and
//! drains every queued request with an error reply — nothing hangs, and
//! `submit` after shutdown returns `Err` instead of panicking.

use crate::error::{Error, Result};
use crate::nn::{NodeClassifier, ParamStore};
use crate::runtime::Engine;
use crate::sampler::SampledSubgraph;
use crate::storage::{FeatureKey, FeatureStore, GraphStore};
use crate::tensor::{argmax_checked, softmax_row};
use crate::util::{BoundedQueue, RecvDeadline};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A classification request for one node.
pub struct Request {
    pub node: u32,
    pub reply_to: mpsc::Sender<Result<Prediction>>,
}

/// A served prediction.
#[derive(Clone, Debug, PartialEq)]
pub struct Prediction {
    pub node: u32,
    pub class: usize,
    pub probabilities: Vec<f32>,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Flush a batch at this many pending requests…
    pub max_batch: usize,
    /// …or after this long, whichever comes first.
    pub max_wait: Duration,
    /// Inference program architecture (HLO backend only).
    pub arch: String,
    /// Sampling fanouts for the model backend (the HLO backend samples
    /// with the fanouts baked into its artifact bucket).
    pub fanouts: Vec<usize>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_batch: 16,
            max_wait: Duration::from_millis(5),
            arch: "gcn".into(),
            fanouts: vec![10, 5],
        }
    }
}

/// Handle to a running inference server.
pub struct InferenceServer {
    inbox: Arc<BoundedQueue<Request>>,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    pub stats: Arc<Mutex<ServeStats>>,
}

/// Serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
}

/// Collect one dynamic batch from `rx`: block for the first request,
/// then accumulate until `max_batch` or `max_wait`, parking in the
/// queue's condvar between arrivals. Returns `None` when the queue is
/// closed and fully drained.
///
/// The bool is true if the queue closed mid-collection — the caller must
/// then reject the batch (shutdown semantics) instead of serving it.
pub(crate) fn collect_batch<T>(
    rx: &BoundedQueue<T>,
    max_batch: usize,
    max_wait: Duration,
) -> Option<(Vec<T>, bool)> {
    let first = rx.recv()?;
    let mut pending = vec![first];
    let mut closed = false;
    let deadline = Instant::now() + max_wait;
    while pending.len() < max_batch {
        match rx.recv_deadline(deadline) {
            RecvDeadline::Item(r) => pending.push(r),
            RecvDeadline::TimedOut => break,
            RecvDeadline::Closed => {
                closed = true;
                break;
            }
        }
    }
    Some((pending, closed))
}

/// Classify one seed from its sampled subgraph with the pure-Rust model:
/// fetch the seed row and its sampled 1-hop neighborhood, embed, score
/// against the class prototypes. Non-finite logits (a poisoned model)
/// become an error reply, never a panic.
pub(crate) fn model_predict(
    model: &NodeClassifier,
    features: &dyn FeatureStore,
    key: &FeatureKey,
    sub: &SampledSubgraph,
) -> Result<Prediction> {
    let node = *sub.nodes.first().ok_or_else(|| Error::Sampler("empty subgraph".into()))?;
    let seed_row = features.get(key, &[node as usize])?;
    let hop1_end = sub.node_offsets.get(1).copied().unwrap_or(sub.nodes.len());
    let hop1: Vec<usize> =
        sub.nodes[sub.num_seeds..hop1_end].iter().map(|&n| n as usize).collect();
    let neighbors = features.get(key, &hop1)?;
    let emb = NodeClassifier::embed(seed_row.row(0), &neighbors);
    let logits = model.logits(&emb);
    let class = argmax_checked(&logits).ok_or_else(|| {
        Error::Runtime(format!("non-finite logits for node {node}: {logits:?}"))
    })?;
    Ok(Prediction { node, class, probabilities: softmax_row(&logits) })
}

/// Reply `Err` to every request in `pending`, then drain and reject
/// whatever else is still queued. Used on shutdown and on backend
/// startup failure so no caller ever blocks forever.
fn reject_all(pending: Vec<Request>, rx: &BoundedQueue<Request>, why: &str) {
    for r in pending {
        let _ = r.reply_to.send(Err(Error::Runtime(why.to_string())));
    }
    while let Some(r) = rx.try_recv() {
        let _ = r.reply_to.send(Err(Error::Runtime(why.to_string())));
    }
}

impl InferenceServer {
    /// Spawn the server thread over a trained model + stores.
    ///
    /// The server thread constructs its *own* [`Engine`] from
    /// `artifact_dir`: PJRT clients are not `Send` (Rc-internal), so each
    /// serving thread owns one — the standard one-client-per-worker
    /// serving topology.
    pub fn spawn<G, F>(
        artifact_dir: std::path::PathBuf,
        graph: Arc<G>,
        features: Arc<F>,
        params: ParamStore,
        cfg: ServeConfig,
    ) -> Result<Self>
    where
        G: GraphStore + 'static,
        F: FeatureStore + 'static,
    {
        let inbox: Arc<BoundedQueue<Request>> = BoundedQueue::new(cfg.max_batch * 8);
        let rx = Arc::clone(&inbox);
        let stop = Arc::new(AtomicBool::new(false));
        let stop_t = Arc::clone(&stop);
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let stats_t = Arc::clone(&stats);
        let program = format!("{}_infer", cfg.arch);
        // Fail fast on config errors before spawning (bucket check needs
        // the manifest; load it cheaply here).
        let bucket_probe = crate::runtime::Manifest::load(&artifact_dir)?.bucket;
        if cfg.max_batch > bucket_probe.s {
            return Err(Error::Runtime(format!(
                "max_batch {} exceeds the artifact seed region {}",
                cfg.max_batch, bucket_probe.s
            )));
        }

        let handle = std::thread::Builder::new()
            .name("pyg2-serve".into())
            .spawn(move || {
                let engine = match Engine::load(&artifact_dir) {
                    Ok(e) => e,
                    Err(e) => {
                        // Close the inbox so callers get errors instead of
                        // queueing into a server that will never serve.
                        log::error!("serve thread could not load engine: {e}");
                        rx.close();
                        reject_all(Vec::new(), &rx, &format!("engine load failed: {e}"));
                        return;
                    }
                };
                let bucket = engine.manifest().bucket.clone();
                let sampler = crate::sampler::NeighborSampler::new(
                    Arc::clone(&graph),
                    crate::sampler::NeighborSamplerConfig {
                        fanouts: bucket.fanouts.clone(),
                        ..Default::default()
                    },
                );
                let shape_bucket = bucket.to_shape_bucket();
                let mut batch_id = 0u64;
                while let Some((pending, closed)) =
                    collect_batch(&rx, cfg.max_batch, cfg.max_wait)
                {
                    if closed || stop_t.load(Ordering::Relaxed) {
                        reject_all(pending, &rx, "server shutting down");
                        continue;
                    }

                    let seeds: Vec<u32> = pending.iter().map(|r| r.node).collect();
                    batch_id += 1;
                    let result = sampler
                        .sample(&seeds, batch_id)
                        .and_then(|sub| {
                            crate::loader::Batch::assemble(
                                sub,
                                features.as_ref(),
                                &FeatureKey::default_x(),
                                None,
                                &shape_bucket,
                            )
                        })
                        .and_then(|batch| {
                            let inputs = Engine::infer_inputs(&batch);
                            engine
                                .run_fused(&program, params.values_ref(), &inputs)
                                .map(|out| (batch, out))
                        });

                    {
                        let mut s = stats_t.lock().unwrap();
                        s.requests += pending.len() as u64;
                        s.batches += 1;
                        s.mean_batch_size = s.requests as f64 / s.batches as f64;
                    }

                    match result {
                        Ok((_batch, out)) => {
                            let logits = match out[0].to_tensor() {
                                Ok(t) => t,
                                Err(e) => {
                                    for r in pending {
                                        let _ = r
                                            .reply_to
                                            .send(Err(Error::Runtime(e.to_string())));
                                    }
                                    continue;
                                }
                            };
                            for (i, r) in pending.into_iter().enumerate() {
                                // NaN logits are a model bug, but they must
                                // become an error reply, not a worker abort.
                                let reply = match argmax_checked(logits.row(i)) {
                                    Some(class) => Ok(Prediction {
                                        node: r.node,
                                        class,
                                        probabilities: softmax_row(logits.row(i)),
                                    }),
                                    None => Err(Error::Runtime(format!(
                                        "non-finite logits for node {}",
                                        r.node
                                    ))),
                                };
                                let _ = r.reply_to.send(reply);
                            }
                        }
                        Err(e) => {
                            let msg = e.to_string();
                            for r in pending {
                                let _ = r.reply_to.send(Err(Error::Runtime(msg.clone())));
                            }
                        }
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn serve thread: {e}")))?;

        Ok(Self { inbox, stop, handle: Some(handle), stats })
    }

    /// Spawn the server thread over the pure-Rust [`NodeClassifier`] —
    /// no AOT artifacts or PJRT runtime required, so this is the backend
    /// CI and the distributed bench exercise.
    ///
    /// Each seed is sampled with `batch_seed = node id`, making its
    /// prediction deterministic and independent of how requests happen
    /// to batch together.
    pub fn spawn_model<G, F>(
        graph: Arc<G>,
        features: Arc<F>,
        model: Arc<NodeClassifier>,
        cfg: ServeConfig,
    ) -> Result<Self>
    where
        G: GraphStore + 'static,
        F: FeatureStore + 'static,
    {
        if cfg.max_batch == 0 {
            return Err(Error::Config("max_batch must be > 0".into()));
        }
        let inbox: Arc<BoundedQueue<Request>> = BoundedQueue::new(cfg.max_batch * 8);
        let rx = Arc::clone(&inbox);
        let stop = Arc::new(AtomicBool::new(false));
        let stop_t = Arc::clone(&stop);
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let stats_t = Arc::clone(&stats);
        let handle = std::thread::Builder::new()
            .name("pyg2-serve".into())
            .spawn(move || {
                let sampler = crate::sampler::NeighborSampler::new(
                    Arc::clone(&graph),
                    crate::sampler::NeighborSamplerConfig {
                        fanouts: cfg.fanouts.clone(),
                        ..Default::default()
                    },
                );
                let key = FeatureKey::default_x();
                while let Some((pending, closed)) =
                    collect_batch(&rx, cfg.max_batch, cfg.max_wait)
                {
                    if closed || stop_t.load(Ordering::Relaxed) {
                        reject_all(pending, &rx, "server shutting down");
                        continue;
                    }
                    {
                        let mut s = stats_t.lock().unwrap();
                        s.requests += pending.len() as u64;
                        s.batches += 1;
                        s.mean_batch_size = s.requests as f64 / s.batches as f64;
                    }
                    for r in pending {
                        let reply = sampler
                            .sample(&[r.node], r.node as u64)
                            .and_then(|sub| {
                                model_predict(&model, features.as_ref(), &key, &sub)
                            });
                        let _ = r.reply_to.send(reply);
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn serve thread: {e}")))?;

        Ok(Self { inbox, stop, handle: Some(handle), stats })
    }

    /// Submit a request; returns the receiver for the prediction, or
    /// `Err` if the server has stopped (no more panicking `expect`).
    pub fn submit(&self, node: u32) -> Result<mpsc::Receiver<Result<Prediction>>> {
        let (tx, rx) = mpsc::channel();
        self.inbox
            .send(Request { node, reply_to: tx })
            .map_err(|_| Error::Runtime("inference server is stopped".into()))?;
        Ok(rx)
    }

    /// Blocking convenience call.
    pub fn predict(&self, node: u32) -> Result<Prediction> {
        self.submit(node)?
            .recv()
            .map_err(|_| Error::Runtime("server dropped request".into()))?
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        // Order matters: raise the stop flag before closing so the worker
        // rejects (rather than serves) anything still queued.
        self.stop.store(true, Ordering::Relaxed);
        self.inbox.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{default_loader, TrainConfig, Trainer};
    use crate::datasets::sbm::{self, SbmConfig};
    use crate::storage::{InMemoryFeatureStore, InMemoryGraphStore};
    use crate::tensor::Tensor;

    fn model_server(
        signal: f32,
        cfg: ServeConfig,
    ) -> (InferenceServer, Vec<i64>) {
        let g = sbm::generate(&SbmConfig {
            num_nodes: 400,
            feature_signal: signal,
            seed: 12,
            ..Default::default()
        })
        .unwrap();
        let labels = g.y.clone().unwrap();
        let num_classes = (*labels.iter().max().unwrap() + 1) as usize;
        let fs = Arc::new(InMemoryFeatureStore::from_tensor(g.x.clone()));
        let model = Arc::new(
            NodeClassifier::fit(fs.as_ref(), &FeatureKey::default_x(), &labels, num_classes)
                .unwrap(),
        );
        let gs = Arc::new(InMemoryGraphStore::from_graph(&g));
        let server = InferenceServer::spawn_model(gs, fs, model, cfg).unwrap();
        (server, labels)
    }

    #[test]
    fn model_backend_serves_batched_predictions() {
        let (server, labels) =
            model_server(2.0, ServeConfig { max_batch: 8, ..Default::default() });
        let mut rxs = Vec::new();
        for node in 100..140u32 {
            rxs.push((node, server.submit(node).unwrap()));
        }
        let mut correct = 0;
        for (node, rx) in rxs {
            let p = rx.recv().unwrap().unwrap();
            assert_eq!(p.node, node);
            assert!((p.probabilities.iter().sum::<f32>() - 1.0).abs() < 1e-4);
            if p.class as i64 == labels[node as usize] {
                correct += 1;
            }
        }
        assert!(correct >= 25, "served accuracy too low: {correct}/40");
        let stats = server.stats.lock().unwrap().clone();
        assert_eq!(stats.requests, 40);
        assert!(
            stats.mean_batch_size > 1.5,
            "dynamic batching should group requests (mean {})",
            stats.mean_batch_size
        );
    }

    #[test]
    fn predictions_are_batch_composition_independent() {
        let cfg = ServeConfig { max_batch: 8, ..Default::default() };
        let (server, _) = model_server(2.0, cfg.clone());
        // Serial: every request its own batch.
        let solo: Vec<Prediction> =
            (50..66u32).map(|n| server.predict(n).unwrap()).collect();
        // Concurrent: the same seeds grouped into dynamic batches.
        let rxs: Vec<_> = (50..66u32).map(|n| server.submit(n).unwrap()).collect();
        for (rx, want) in rxs.into_iter().zip(&solo) {
            assert_eq!(&rx.recv().unwrap().unwrap(), want);
        }
    }

    #[test]
    fn shutdown_drains_pending_with_errors_and_submit_fails_after() {
        // A huge max_wait would park the worker mid-batch for 30s; drop
        // must still resolve every outstanding request promptly.
        let (server, _) = model_server(1.0, ServeConfig {
            max_batch: 64,
            max_wait: Duration::from_secs(30),
            ..Default::default()
        });
        let rxs: Vec<_> = (0..5u32).map(|n| server.submit(n).unwrap()).collect();
        let t = Instant::now();
        drop(server);
        for rx in rxs {
            let reply = rx.recv().expect("reply channel must not just vanish");
            assert!(reply.is_err(), "shutdown must reject, got {reply:?}");
        }
        assert!(t.elapsed() < Duration::from_secs(10), "drop hung on max_wait");
    }

    #[test]
    fn nan_model_output_is_an_error_reply_not_a_panic() {
        let g = sbm::generate(&SbmConfig { num_nodes: 50, seed: 3, ..Default::default() })
            .unwrap();
        let dim = g.x.cols();
        let fs = Arc::new(InMemoryFeatureStore::from_tensor(g.x.clone()));
        let gs = Arc::new(InMemoryGraphStore::from_graph(&g));
        // Poisoned prototypes: every logit is NaN.
        let model = Arc::new(NodeClassifier::from_prototypes(Tensor::full(
            vec![2, dim],
            f32::NAN,
        )));
        let server =
            InferenceServer::spawn_model(gs, fs, model, ServeConfig::default()).unwrap();
        let got = server.predict(7);
        assert!(got.is_err(), "NaN logits must be an error reply: {got:?}");
        // The worker survived: the server still answers.
        assert!(server.predict(8).is_err());
    }

    #[test]
    fn serves_batched_predictions() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::load("artifacts").unwrap();
        let b = engine.manifest().bucket.clone();
        let g = sbm::generate(&SbmConfig {
            num_nodes: 500,
            num_blocks: b.c,
            feature_dim: b.f,
            feature_signal: 1.5,
            seed: 12,
            ..Default::default()
        })
        .unwrap();
        let labels = g.y.clone().unwrap();
        let loader = default_loader(&engine, &g, (0..256).collect(), 1);
        let report = Trainer::new(
            &engine,
            TrainConfig { epochs: 10, log_every: 0, ..Default::default() },
        )
        .train(&loader)
        .unwrap();

        let gs = Arc::new(InMemoryGraphStore::from_graph(&g));
        let fs = Arc::new(InMemoryFeatureStore::from_tensor(g.x.clone()));
        let server = InferenceServer::spawn(
            "artifacts".into(),
            gs,
            fs,
            report.final_params.clone(),
            ServeConfig { max_batch: 8, ..Default::default() },
        )
        .unwrap();

        // Concurrent clients.
        let mut rxs = Vec::new();
        for node in 300..340u32 {
            rxs.push((node, server.submit(node).unwrap()));
        }
        let mut correct = 0;
        for (node, rx) in rxs {
            let p = rx.recv().unwrap().unwrap();
            assert_eq!(p.node, node);
            assert!((p.probabilities.iter().sum::<f32>() - 1.0).abs() < 1e-4);
            if p.class as i64 == labels[node as usize] {
                correct += 1;
            }
        }
        assert!(correct >= 20, "served accuracy too low: {correct}/40");

        let stats = server.stats.lock().unwrap().clone();
        assert_eq!(stats.requests, 40);
        assert!(
            stats.mean_batch_size > 1.5,
            "dynamic batching should group requests (mean {})",
            stats.mean_batch_size
        );
    }
}
