//! Inference serving: a request router with dynamic batching.
//!
//! The deployment half of the blueprint (TorchScript/serving in §2.1):
//! clients submit single-node classification requests; the server
//! accumulates them into a batch until `max_batch` seeds or `max_wait`
//! elapses (whichever first), runs one sampled+padded batch through the
//! inference HLO, and routes per-seed predictions back to their callers.
//! The batching policy is the standard dynamic-batching tradeoff
//! (throughput vs tail latency) of GNN serving systems.

use crate::error::{Error, Result};
use crate::nn::ParamStore;
use crate::runtime::Engine;
use crate::storage::{FeatureStore, GraphStore};
use crate::tensor::softmax_row;
use crate::util::BoundedQueue;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A classification request for one node.
pub struct Request {
    pub node: u32,
    pub reply_to: mpsc::Sender<Result<Prediction>>,
}

/// A served prediction.
#[derive(Clone, Debug)]
pub struct Prediction {
    pub node: u32,
    pub class: usize,
    pub probabilities: Vec<f32>,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Flush a batch at this many pending requests…
    pub max_batch: usize,
    /// …or after this long, whichever comes first.
    pub max_wait: Duration,
    pub arch: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { max_batch: 16, max_wait: Duration::from_millis(5), arch: "gcn".into() }
    }
}

/// Handle to a running inference server.
pub struct InferenceServer {
    inbox: Arc<BoundedQueue<Request>>,
    handle: Option<JoinHandle<()>>,
    pub stats: Arc<std::sync::Mutex<ServeStats>>,
}

/// Serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
}

impl InferenceServer {
    /// Spawn the server thread over a trained model + stores.
    ///
    /// The server thread constructs its *own* [`Engine`] from
    /// `artifact_dir`: PJRT clients are not `Send` (Rc-internal), so each
    /// serving thread owns one — the standard one-client-per-worker
    /// serving topology.
    pub fn spawn<G, F>(
        artifact_dir: std::path::PathBuf,
        graph: Arc<G>,
        features: Arc<F>,
        params: ParamStore,
        cfg: ServeConfig,
    ) -> Result<Self>
    where
        G: GraphStore + 'static,
        F: FeatureStore + 'static,
    {
        let inbox: Arc<BoundedQueue<Request>> = BoundedQueue::new(cfg.max_batch * 8);
        let rx = Arc::clone(&inbox);
        let stats = Arc::new(std::sync::Mutex::new(ServeStats::default()));
        let stats_t = Arc::clone(&stats);
        let program = format!("{}_infer", cfg.arch);
        // Fail fast on config errors before spawning (bucket check needs
        // the manifest; load it cheaply here).
        let bucket_probe = crate::runtime::Manifest::load(&artifact_dir)?.bucket;
        if cfg.max_batch > bucket_probe.s {
            return Err(Error::Runtime(format!(
                "max_batch {} exceeds the artifact seed region {}",
                cfg.max_batch, bucket_probe.s
            )));
        }

        let handle = std::thread::Builder::new()
            .name("pyg2-serve".into())
            .spawn(move || {
                let engine = match Engine::load(&artifact_dir) {
                    Ok(e) => e,
                    Err(e) => {
                        log::error!("serve thread could not load engine: {e}");
                        return;
                    }
                };
                let bucket = engine.manifest().bucket.clone();
                let sampler = crate::sampler::NeighborSampler::new(
                    Arc::clone(&graph),
                    crate::sampler::NeighborSamplerConfig {
                        fanouts: bucket.fanouts.clone(),
                        ..Default::default()
                    },
                );
                let shape_bucket = bucket.to_shape_bucket();
                let mut batch_id = 0u64;
                loop {
                    // Dynamic batching: block for the first request, then
                    // drain until max_batch or max_wait.
                    let Some(first) = rx.recv() else { break };
                    let mut pending = vec![first];
                    let deadline = Instant::now() + cfg.max_wait;
                    while pending.len() < cfg.max_batch && Instant::now() < deadline {
                        match rx.try_recv() {
                            Some(r) => pending.push(r),
                            None => std::thread::yield_now(),
                        }
                    }

                    let seeds: Vec<u32> = pending.iter().map(|r| r.node).collect();
                    batch_id += 1;
                    let result = sampler
                        .sample(&seeds, batch_id)
                        .and_then(|sub| {
                            crate::loader::Batch::assemble(
                                sub,
                                features.as_ref(),
                                &crate::storage::FeatureKey::default_x(),
                                None,
                                &shape_bucket,
                            )
                        })
                        .and_then(|batch| {
                            let inputs = Engine::infer_inputs(&batch);
                            engine
                                .run_fused(&program, params.values_ref(), &inputs)
                                .map(|out| (batch, out))
                        });

                    {
                        let mut s = stats_t.lock().unwrap();
                        s.requests += pending.len() as u64;
                        s.batches += 1;
                        s.mean_batch_size = s.requests as f64 / s.batches as f64;
                    }

                    match result {
                        Ok((_batch, out)) => {
                            let logits = match out[0].to_tensor() {
                                Ok(t) => t,
                                Err(e) => {
                                    for r in pending {
                                        let _ = r
                                            .reply_to
                                            .send(Err(Error::Runtime(e.to_string())));
                                    }
                                    continue;
                                }
                            };
                            for (i, r) in pending.into_iter().enumerate() {
                                let probs = softmax_row(logits.row(i));
                                let class = probs
                                    .iter()
                                    .enumerate()
                                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                                    .map(|(c, _)| c)
                                    .unwrap_or(0);
                                let _ = r.reply_to.send(Ok(Prediction {
                                    node: r.node,
                                    class,
                                    probabilities: probs,
                                }));
                            }
                        }
                        Err(e) => {
                            let msg = e.to_string();
                            for r in pending {
                                let _ = r.reply_to.send(Err(Error::Runtime(msg.clone())));
                            }
                        }
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn serve thread: {e}")))?;

        Ok(Self { inbox, handle: Some(handle), stats })
    }

    /// Submit a request; returns the receiver for the prediction.
    pub fn submit(&self, node: u32) -> mpsc::Receiver<Result<Prediction>> {
        let (tx, rx) = mpsc::channel();
        self.inbox
            .send(Request { node, reply_to: tx })
            .expect("server stopped");
        rx
    }

    /// Blocking convenience call.
    pub fn predict(&self, node: u32) -> Result<Prediction> {
        self.submit(node)
            .recv()
            .map_err(|_| Error::Runtime("server dropped request".into()))?
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.inbox.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{default_loader, TrainConfig, Trainer};
    use crate::datasets::sbm::{self, SbmConfig};

    #[test]
    fn serves_batched_predictions() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::load("artifacts").unwrap();
        let b = engine.manifest().bucket.clone();
        let g = sbm::generate(&SbmConfig {
            num_nodes: 500,
            num_blocks: b.c,
            feature_dim: b.f,
            feature_signal: 1.5,
            seed: 12,
            ..Default::default()
        })
        .unwrap();
        let labels = g.y.clone().unwrap();
        let loader = default_loader(&engine, &g, (0..256).collect(), 1);
        let report = Trainer::new(
            &engine,
            TrainConfig { epochs: 10, log_every: 0, ..Default::default() },
        )
        .train(&loader)
        .unwrap();

        let gs = Arc::new(crate::storage::InMemoryGraphStore::from_graph(&g));
        let fs = Arc::new(crate::storage::InMemoryFeatureStore::from_tensor(g.x.clone()));
        let server = InferenceServer::spawn(
            "artifacts".into(),
            gs,
            fs,
            report.final_params.clone(),
            ServeConfig { max_batch: 8, ..Default::default() },
        )
        .unwrap();

        // Concurrent clients.
        let mut rxs = Vec::new();
        for node in 300..340u32 {
            rxs.push((node, server.submit(node)));
        }
        let mut correct = 0;
        for (node, rx) in rxs {
            let p = rx.recv().unwrap().unwrap();
            assert_eq!(p.node, node);
            assert!((p.probabilities.iter().sum::<f32>() - 1.0).abs() < 1e-4);
            if p.class as i64 == labels[node as usize] {
                correct += 1;
            }
        }
        assert!(correct >= 20, "served accuracy too low: {correct}/40");

        let stats = server.stats.lock().unwrap().clone();
        assert_eq!(stats.requests, 40);
        assert!(
            stats.mean_batch_size > 1.5,
            "dynamic batching should group requests (mean {})",
            stats.mean_batch_size
        );
    }
}
