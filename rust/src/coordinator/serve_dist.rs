//! Distributed inference serving: multi-worker dynamic batching over
//! the partitioned stores.
//!
//! This is `coordinator::serve` re-platformed onto the `dist`/`persist`
//! stack (the production-serving tier of the §2.1 deployment blueprint):
//!
//! * **N server workers, one admission queue.** Clients submit into a
//!   shared bounded [`BoundedQueue`]; every worker thread pulls its own
//!   dynamic batches from it (`max_batch`/`max_wait`, parked in
//!   `recv_deadline` — no busy-wait). Workers share the
//!   [`PartitionedFeatureStore`]/[`PartitionedGraphStore`] pair, so the
//!   halo replica, the bounded row/adjacency LRUs of a mounted store,
//!   and the [`crate::dist::AsyncRouter`] fetch pool are all shared
//!   serving-wide; each worker owns its own
//!   [`DistNeighborSampler`] (samplers are cheap and stateless).
//! * **Per-request deadline budgets.** A request may carry a latency
//!   budget; if it is already past due when a worker dequeues it — the
//!   queue backed up beyond its SLO — it is rejected with
//!   [`Error::Deadline`] instead of being served late or queued
//!   unboundedly.
//! * **Paged k-hop sampling.** The sampler runs against the
//!   partition-aware stores directly (resident or `--page-adj`
//!   demand-paged adjacency); serving never materializes a merged CSR.
//! * **Prediction identity.** Each seed is sampled with
//!   `batch_seed = node id`, and [`DistNeighborSampler`] is
//!   seed-for-seed identical to the in-memory sampler — so predictions
//!   are a pure function of the node, independent of worker count,
//!   batch composition, or store backing. The serve tests assert
//!   multi-worker mounted serving equals the single-store server.
//!
//! [`run_traffic`] drives a closed-loop Zipf-skewed client fleet (the
//! recommendation-serving access pattern, which is what finally makes
//! the LRU caches earn their keep) and reports p50/p95/p99 latency plus
//! throughput; `benches/bench_serve_dist.rs` sweeps it across
//! `max_batch` × `max_wait` × worker count at 2/4/8 partitions.

use super::serve::{collect_batch, model_predict, Prediction};
use crate::dist::{
    DistNeighborSampler, MountPrefetcher, PartitionedFeatureStore, PartitionedGraphStore,
};
use crate::error::{Error, Result};
use crate::nn::NodeClassifier;
use crate::obs;
use crate::sampler::NeighborSamplerConfig;
use crate::storage::{FeatureKey, FeatureStore};
use crate::util::{BoundedQueue, Rng, Samples, Zipf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A classification request for one node, with an optional SLO.
pub struct DistRequest {
    pub node: u32,
    /// Absolute deadline; a worker dequeueing the request after this
    /// instant rejects it with [`Error::Deadline`].
    pub deadline: Option<Instant>,
    /// Admission timestamp feeding the `queue_wait` stage histogram at
    /// dequeue; `None` while telemetry is disabled (no clock read).
    pub admitted: Option<Instant>,
    pub reply_to: mpsc::Sender<Result<Prediction>>,
}

/// Distributed-server configuration.
#[derive(Clone, Debug)]
pub struct ServeDistConfig {
    /// Flush a worker's batch at this many pending requests…
    pub max_batch: usize,
    /// …or after this long, whichever comes first.
    pub max_wait: Duration,
    /// Server worker threads pulling from the shared admission queue.
    pub workers: usize,
    /// Sampling fanouts per hop.
    pub fanouts: Vec<usize>,
    /// Admission queue capacity (bounds memory under overload; the
    /// deadline check is what bounds *latency*).
    pub queue_capacity: usize,
    /// Pipeline prefetch on mounted stores (`--prefetch`): as soon as a
    /// dynamic batch is dequeued, a shared [`MountPrefetcher`] warms its
    /// seeds' feature rows and in-edge lists off the demand path,
    /// overlapping the per-seed sampling below. Cache warming only —
    /// predictions are unchanged. Ignored on non-mounted stores.
    pub prefetch: bool,
}

impl Default for ServeDistConfig {
    fn default() -> Self {
        Self {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            workers: 2,
            fanouts: vec![10, 5],
            queue_capacity: 512,
            prefetch: false,
        }
    }
}

/// Aggregate serving counters across all workers — a view assembled
/// from the server's scoped `serve.*` registry counters by
/// [`DistInferenceServer::stats`].
#[derive(Clone, Debug, Default)]
pub struct ServeDistStats {
    /// Requests served (admitted, sampled, replied — Ok or model error).
    pub requests: u64,
    /// Dynamic batches processed.
    pub batches: u64,
    /// Requests rejected at dequeue for a missed deadline budget.
    pub deadline_rejected: u64,
    /// Error replies (sampler/fetch/model failures; excludes deadline
    /// rejections and shutdown drains).
    pub errors: u64,
}

impl ServeDistStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// Registry handles of one server instance (scope `serve`), shared by
/// its workers; [`DistInferenceServer::stats`] reads through them.
#[derive(Clone)]
struct ServeCounters {
    requests: Arc<obs::Counter>,
    batches: Arc<obs::Counter>,
    deadline_rejected: Arc<obs::Counter>,
    errors: Arc<obs::Counter>,
}

impl ServeCounters {
    fn register() -> Self {
        let scope = obs::Scope::new("serve");
        Self {
            requests: scope.counter("requests"),
            batches: scope.counter("batches"),
            deadline_rejected: scope.counter("deadline_rejected"),
            errors: scope.counter("errors"),
        }
    }

    fn stats(&self) -> ServeDistStats {
        ServeDistStats {
            requests: self.requests.get(),
            batches: self.batches.get(),
            deadline_rejected: self.deadline_rejected.get(),
            errors: self.errors.get(),
        }
    }
}

/// Handle to a running multi-worker distributed inference server.
pub struct DistInferenceServer {
    inbox: Arc<BoundedQueue<DistRequest>>,
    stop: Arc<AtomicBool>,
    handles: Vec<JoinHandle<()>>,
    counters: ServeCounters,
    features: Arc<PartitionedFeatureStore>,
    graph: Arc<PartitionedGraphStore>,
    prefetcher: Option<Arc<MountPrefetcher>>,
}

fn reject_all_dist(pending: Vec<DistRequest>, rx: &BoundedQueue<DistRequest>, why: &str) {
    for r in pending {
        let _ = r.reply_to.send(Err(Error::Runtime(why.to_string())));
    }
    while let Some(r) = rx.try_recv() {
        let _ = r.reply_to.send(Err(Error::Runtime(why.to_string())));
    }
}

impl DistInferenceServer {
    /// Spawn `cfg.workers` server threads over the shared partitioned
    /// stores (in-memory, mounted, or mounted with paged adjacency — the
    /// server never sees the difference) and the shared model.
    pub fn spawn(
        graph: Arc<PartitionedGraphStore>,
        features: Arc<PartitionedFeatureStore>,
        model: Arc<NodeClassifier>,
        cfg: ServeDistConfig,
    ) -> Result<Self> {
        if cfg.workers == 0 {
            return Err(Error::Config("serve-dist needs at least one worker".into()));
        }
        if cfg.max_batch == 0 {
            return Err(Error::Config("max_batch must be > 0".into()));
        }
        if graph.typed_router().num_node_types() != 1 {
            return Err(Error::Config(
                "serve-dist covers homogeneous stores; typed serving is future work".into(),
            ));
        }
        let inbox: Arc<BoundedQueue<DistRequest>> = BoundedQueue::new_observed(
            cfg.queue_capacity.max(cfg.max_batch * cfg.workers),
            "serve.queue",
        );
        let stop = Arc::new(AtomicBool::new(false));
        let counters = ServeCounters::register();
        // Batched union prefetch only pays off when misses are
        // expensive and cached afterwards — i.e. on a mounted store
        // with a row LRU. On an in-memory store it would just double
        // every fetch (and its router counters).
        let prefetch = features.row_cache_stats().is_some();
        // Pipeline prefetch: one warmer shared by every worker, so a
        // dequeued batch's seed rows and in-lists warm while that
        // worker samples. No-op warms on non-mounted stores.
        let prefetcher = cfg.prefetch.then(|| {
            Arc::new(MountPrefetcher::new(
                Arc::clone(&graph),
                Arc::clone(&features),
                crate::storage::DEFAULT_GROUP,
            ))
        });

        let mut handles = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let rx = Arc::clone(&inbox);
            let stop_t = Arc::clone(&stop);
            let stats_t = counters.clone();
            let graph_t = Arc::clone(&graph);
            let features_t = Arc::clone(&features);
            let model_t = Arc::clone(&model);
            let cfg_t = cfg.clone();
            let pf_t = prefetcher.clone();
            let handle = std::thread::Builder::new()
                .name(format!("pyg2-serve-{w}"))
                .spawn(move || {
                    worker_loop(
                        rx, stop_t, stats_t, graph_t, features_t, model_t, cfg_t, prefetch, pf_t,
                    )
                })
                .map_err(|e| Error::Runtime(format!("spawn serve worker {w}: {e}")))?;
            handles.push(handle);
        }
        Ok(Self { inbox, stop, handles, counters, features, graph, prefetcher })
    }

    /// Submit a request with an optional latency budget; returns the
    /// reply receiver, or `Err` if the server has stopped.
    pub fn submit(
        &self,
        node: u32,
        budget: Option<Duration>,
    ) -> Result<mpsc::Receiver<Result<Prediction>>> {
        let (tx, rx) = mpsc::channel();
        let deadline = budget.map(|b| Instant::now() + b);
        let admitted = obs::enabled().then(Instant::now);
        self.inbox
            .send(DistRequest { node, deadline, admitted, reply_to: tx })
            .map_err(|_| Error::Runtime("inference server is stopped".into()))?;
        Ok(rx)
    }

    /// Blocking convenience call without a deadline budget.
    pub fn predict(&self, node: u32) -> Result<Prediction> {
        self.predict_within(node, None)
    }

    /// Blocking call with a latency budget: `Err(Error::Deadline)` if
    /// the request could not be dequeued within its SLO.
    pub fn predict_within(&self, node: u32, budget: Option<Duration>) -> Result<Prediction> {
        self.submit(node, budget)?
            .recv()
            .map_err(|_| Error::Runtime("server dropped request".into()))?
    }

    /// Snapshot of the aggregate serving counters (a view over the
    /// server's registry reads).
    pub fn stats(&self) -> ServeDistStats {
        self.counters.stats()
    }

    /// The shared feature store (for cache/IO ledger inspection).
    pub fn features(&self) -> &Arc<PartitionedFeatureStore> {
        &self.features
    }

    /// The shared graph store (for adjacency ledger inspection).
    pub fn graph(&self) -> &Arc<PartitionedGraphStore> {
        &self.graph
    }

    /// Current admission-queue depth.
    pub fn queue_depth(&self) -> usize {
        self.inbox.len()
    }

    /// Pipeline-prefetch counters, when `cfg.prefetch` installed a
    /// [`MountPrefetcher`].
    pub fn prefetch_stats(&self) -> Option<crate::dist::PrefetchStats> {
        self.prefetcher.as_ref().map(|p| p.stats())
    }
}

impl Drop for DistInferenceServer {
    fn drop(&mut self) {
        // Stop flag first so workers reject (rather than serve) whatever
        // is still queued, then close to wake every parked worker.
        self.stop.store(true, Ordering::Relaxed);
        self.inbox.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One server worker: pull dynamic batches, enforce deadlines at
/// dequeue, sample each admitted seed deterministically, warm the shared
/// caches with one unioned fetch, classify, reply.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    rx: Arc<BoundedQueue<DistRequest>>,
    stop: Arc<AtomicBool>,
    stats: ServeCounters,
    graph: Arc<PartitionedGraphStore>,
    features: Arc<PartitionedFeatureStore>,
    model: Arc<NodeClassifier>,
    cfg: ServeDistConfig,
    prefetch: bool,
    prefetcher: Option<Arc<MountPrefetcher>>,
) {
    let sampler = DistNeighborSampler::new(
        graph,
        NeighborSamplerConfig { fanouts: cfg.fanouts.clone(), ..Default::default() },
    );
    let key = FeatureKey::default_x();
    while let Some((pending, closed)) = collect_batch(&rx, cfg.max_batch, cfg.max_wait) {
        if closed || stop.load(Ordering::Relaxed) {
            reject_all_dist(pending, &rx, "server shutting down");
            continue;
        }

        // Deadline budgets are enforced at dequeue: if the queue backed
        // up past a request's SLO, serving it late helps nobody — shed
        // it now so the batch only carries work that can still meet its
        // budget.
        let now = Instant::now();
        let mut live = Vec::with_capacity(pending.len());
        let mut shed = 0u64;
        for r in pending {
            if r.deadline.is_some_and(|d| now > d) {
                shed += 1;
                let _ = r.reply_to.send(Err(Error::Deadline(format!(
                    "node {}: request missed its latency budget in the queue",
                    r.node
                ))));
            } else {
                if let Some(t) = r.admitted {
                    obs::record_stage("queue_wait", t.elapsed().as_micros() as u64);
                }
                live.push(r);
            }
        }

        stats.deadline_rejected.add(shed);
        if live.is_empty() {
            continue;
        }
        stats.requests.add(live.len() as u64);
        stats.batches.inc();

        // Pipeline prefetch: hand the freshly dequeued batch's seeds to
        // the shared warmer so their rows and in-lists stream off disk
        // while this worker samples them. Warming only — the demand
        // path below is untouched.
        if let Some(pf) = &prefetcher {
            let seeds: Vec<u32> = live.iter().map(|r| r.node).collect();
            pf.schedule(&seeds);
        }

        // Per-seed deterministic sampling: batch_seed = node id, so a
        // node's subgraph (hence its prediction) does not depend on
        // which requests happened to share its batch or worker.
        let sampled: Vec<(DistRequest, Result<crate::sampler::SampledSubgraph>)> = live
            .into_iter()
            .map(|r| {
                let sub = sampler.sample(&[r.node], r.node as u64);
                (r, sub)
            })
            .collect();

        // One unioned fetch pulls every distinct row of the batch
        // through the router — remote partitions coalesced (and
        // overlapped, when an AsyncRouter is attached) — so the
        // per-seed classification fetches below hit the warm row LRU.
        if prefetch {
            let mut union: Vec<usize> = sampled
                .iter()
                .filter_map(|(_, s)| s.as_ref().ok())
                .flat_map(|s| s.nodes.iter().map(|&n| n as usize))
                .collect();
            union.sort_unstable();
            union.dedup();
            if !union.is_empty() {
                let _span = obs::span("feature_fetch");
                let _ = features.get(&key, &union);
            }
        }

        let mut errors = 0u64;
        for (r, sub) in sampled {
            let reply = sub.and_then(|sub| {
                let _span = obs::span("infer");
                model_predict(&model, features.as_ref(), &key, &sub)
            });
            if reply.is_err() {
                errors += 1;
            }
            let _span = obs::span("reply");
            let _ = r.reply_to.send(reply);
        }
        if errors > 0 {
            stats.errors.add(errors);
        }
    }
}

/// Closed-loop traffic generator configuration.
#[derive(Clone, Debug)]
pub struct TrafficConfig {
    /// Concurrent closed-loop clients (each waits for its reply before
    /// sending the next request).
    pub clients: usize,
    /// Requests each client sends.
    pub requests_per_client: usize,
    /// Zipf skew of node popularity (0 = uniform; ~1 = classic Zipf —
    /// the recommendation-serving access pattern).
    pub zipf_exponent: f64,
    /// Optional per-request latency budget.
    pub budget: Option<Duration>,
    pub seed: u64,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        Self {
            clients: 4,
            requests_per_client: 64,
            zipf_exponent: 1.1,
            budget: None,
            seed: 0,
        }
    }
}

/// What a traffic run observed, client-side.
#[derive(Clone, Debug)]
pub struct TrafficReport {
    pub completed: u64,
    pub deadline_rejected: u64,
    pub errors: u64,
    /// Client threads that died (panicked) before reporting their
    /// tally; their requests are missing from the other counters.
    pub client_failures: u64,
    /// End-to-end latency samples (seconds) of completed requests.
    pub latency: Samples,
    pub elapsed: Duration,
}

impl TrafficReport {
    /// Completed requests per second of wall-clock.
    pub fn throughput_rps(&self) -> f64 {
        if self.elapsed.as_secs_f64() > 0.0 {
            self.completed as f64 / self.elapsed.as_secs_f64()
        } else {
            0.0
        }
    }

    pub fn p50_ms(&self) -> f64 {
        self.latency.percentile(50.0) * 1e3
    }

    pub fn p95_ms(&self) -> f64 {
        self.latency.percentile(95.0) * 1e3
    }

    pub fn p99_ms(&self) -> f64 {
        self.latency.percentile(99.0) * 1e3
    }
}

impl std::fmt::Display for TrafficReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} ok / {} deadline-rejected / {} errors in {:.2}s ({:.0} req/s) \
             p50 {:.3}ms p95 {:.3}ms p99 {:.3}ms",
            self.completed,
            self.deadline_rejected,
            self.errors,
            self.elapsed.as_secs_f64(),
            self.throughput_rps(),
            self.p50_ms(),
            self.p95_ms(),
            self.p99_ms(),
        )?;
        if self.client_failures > 0 {
            write!(f, " ({} client threads died)", self.client_failures)?;
        }
        Ok(())
    }
}

/// Drive a closed-loop client fleet against the server: each client
/// draws nodes from a shared Zipf popularity distribution over
/// `[0, num_nodes)` (deterministic per `cfg.seed`/client index), submits
/// with the configured budget, and blocks for the reply. Returns the
/// merged latency/outcome report.
pub fn run_traffic(
    server: &DistInferenceServer,
    num_nodes: usize,
    cfg: &TrafficConfig,
) -> TrafficReport {
    struct ClientTally {
        completed: u64,
        rejected: u64,
        errors: u64,
        latencies: Vec<f64>,
    }

    let zipf = Zipf::new(num_nodes, cfg.zipf_exponent);
    let base = Rng::new(cfg.seed);
    let t0 = Instant::now();
    let tallies: Vec<Option<ClientTally>> = std::thread::scope(|scope| {
        let mut joins = Vec::with_capacity(cfg.clients);
        for c in 0..cfg.clients {
            let mut rng = base.fork(c as u64);
            let zipf = &zipf;
            joins.push(scope.spawn(move || {
                let mut tally = ClientTally {
                    completed: 0,
                    rejected: 0,
                    errors: 0,
                    latencies: Vec::with_capacity(cfg.requests_per_client),
                };
                for _ in 0..cfg.requests_per_client {
                    let node = zipf.sample(&mut rng) as u32;
                    let t = Instant::now();
                    match server.predict_within(node, cfg.budget) {
                        Ok(_) => {
                            tally.completed += 1;
                            tally.latencies.push(t.elapsed().as_secs_f64());
                        }
                        Err(Error::Deadline(_)) => tally.rejected += 1,
                        Err(_) => tally.errors += 1,
                    }
                }
                tally
            }));
        }
        // A client thread panicking (a server bug surfacing client-side)
        // must not take the whole traffic report down with it: count the
        // loss and surface it instead.
        joins.into_iter().map(|j| j.join().ok()).collect()
    });
    let elapsed = t0.elapsed();

    let mut report = TrafficReport {
        completed: 0,
        deadline_rejected: 0,
        errors: 0,
        client_failures: 0,
        latency: Samples::new(),
        elapsed,
    };
    for t in tallies {
        let Some(t) = t else {
            report.client_failures += 1;
            continue;
        };
        report.completed += t.completed;
        report.deadline_rejected += t.rejected;
        report.errors += t.errors;
        for l in t.latencies {
            report.latency.push(l);
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{partitioned_stores, DistOptions};
    use crate::datasets::sbm::{self, SbmConfig};
    use crate::partition::ldg_partition;

    fn sbm_fixture() -> (crate::graph::Graph, crate::partition::Partitioning) {
        let g = sbm::generate(&SbmConfig {
            num_nodes: 300,
            feature_signal: 2.0,
            seed: 8,
            ..Default::default()
        })
        .unwrap();
        let p = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
        (g, p)
    }

    fn fit_model(g: &crate::graph::Graph) -> Arc<NodeClassifier> {
        let labels = g.y.clone().unwrap();
        let classes = (*labels.iter().max().unwrap() + 1) as usize;
        let fs = crate::storage::InMemoryFeatureStore::from_tensor(g.x.clone());
        Arc::new(
            NodeClassifier::fit(&fs, &FeatureKey::default_x(), &labels, classes).unwrap(),
        )
    }

    #[test]
    fn multi_worker_serving_over_partitioned_stores() {
        let (g, p) = sbm_fixture();
        let model = fit_model(&g);
        let (gs, fs) = partitioned_stores(&g, &p, 0, DistOptions::default()).unwrap();
        let server = DistInferenceServer::spawn(
            gs,
            fs,
            model,
            ServeDistConfig { workers: 3, max_batch: 8, ..Default::default() },
        )
        .unwrap();
        let rxs: Vec<_> =
            (0..60u32).map(|n| (n, server.submit(n, None).unwrap())).collect();
        let labels = g.y.as_ref().unwrap();
        let mut correct = 0;
        for (node, rx) in rxs {
            let pred = rx.recv().unwrap().unwrap();
            assert_eq!(pred.node, node);
            if pred.class as i64 == labels[node as usize] {
                correct += 1;
            }
        }
        assert!(correct >= 40, "served accuracy too low: {correct}/60");
        let stats = server.stats();
        assert_eq!(stats.requests, 60);
        assert!(stats.batches > 0);
        assert_eq!(stats.deadline_rejected, 0);
    }

    #[test]
    fn zero_budget_requests_are_rejected_with_deadline_error() {
        let (g, p) = sbm_fixture();
        let model = fit_model(&g);
        let (gs, fs) = partitioned_stores(&g, &p, 0, DistOptions::default()).unwrap();
        let server = DistInferenceServer::spawn(
            gs,
            fs,
            model,
            // One worker + a long max_wait: submissions queue behind the
            // batch window, so an already-expired budget is shed.
            ServeDistConfig {
                workers: 1,
                max_batch: 64,
                max_wait: Duration::from_millis(50),
                ..Default::default()
            },
        )
        .unwrap();
        let got = server.predict_within(3, Some(Duration::ZERO));
        match got {
            Err(Error::Deadline(_)) => {}
            other => panic!("expected a deadline rejection, got {other:?}"),
        }
        assert!(server.stats().deadline_rejected >= 1);
        // The server still serves budget-free requests afterwards.
        assert!(server.predict(3).is_ok());
    }

    #[test]
    fn traffic_generator_reports_skewed_closed_loop_run() {
        let (g, p) = sbm_fixture();
        let n = g.num_nodes();
        let model = fit_model(&g);
        let (gs, fs) = partitioned_stores(&g, &p, 0, DistOptions::default()).unwrap();
        let server = DistInferenceServer::spawn(
            gs,
            fs,
            model,
            ServeDistConfig { workers: 2, ..Default::default() },
        )
        .unwrap();
        let report = run_traffic(
            &server,
            n,
            &TrafficConfig {
                clients: 3,
                requests_per_client: 20,
                ..Default::default()
            },
        );
        assert_eq!(report.completed, 60, "{report}");
        assert_eq!(report.errors, 0, "{report}");
        assert_eq!(report.client_failures, 0, "{report}");
        assert_eq!(report.latency.len() as u64, report.completed);
        assert!(report.throughput_rps() > 0.0);
        assert!(report.p50_ms() <= report.p95_ms() && report.p95_ms() <= report.p99_ms());
        // Dead client threads show up in the report, not as a panic of
        // the whole traffic run.
        assert!(!format!("{report}").contains("client threads died"));
        let mut broken = report.clone();
        broken.client_failures = 2;
        assert!(format!("{broken}").contains("2 client threads died"));
    }

    #[test]
    fn shutdown_drains_queued_requests_with_errors() {
        let (g, p) = sbm_fixture();
        let model = fit_model(&g);
        let (gs, fs) = partitioned_stores(&g, &p, 0, DistOptions::default()).unwrap();
        let server = DistInferenceServer::spawn(
            gs,
            fs,
            model,
            ServeDistConfig {
                workers: 1,
                max_batch: 64,
                max_wait: Duration::from_secs(30),
                ..Default::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..6u32).map(|n| server.submit(n, None).unwrap()).collect();
        let t = Instant::now();
        drop(server);
        for rx in rxs {
            let reply = rx.recv().expect("reply channel must resolve");
            assert!(reply.is_err(), "shutdown must reject, got {reply:?}");
        }
        assert!(t.elapsed() < Duration::from_secs(10), "drop hung on max_wait");
    }

    #[test]
    fn spawn_rejects_degenerate_configs() {
        let (g, p) = sbm_fixture();
        let model = fit_model(&g);
        let (gs, fs) = partitioned_stores(&g, &p, 0, DistOptions::default()).unwrap();
        assert!(DistInferenceServer::spawn(
            Arc::clone(&gs),
            Arc::clone(&fs),
            Arc::clone(&model),
            ServeDistConfig { workers: 0, ..Default::default() },
        )
        .is_err());
        assert!(DistInferenceServer::spawn(
            gs,
            fs,
            model,
            ServeDistConfig { max_batch: 0, ..Default::default() },
        )
        .is_err());
    }
}
