//! Training coordinator: drives the loader → runtime pipeline for both
//! execution modes, tracks metrics, and owns the parameter update cycle.
//! This is the Rust-side "training loop that looks identical regardless
//! of backend" promised by the FeatureStore/GraphStore split (§2.3).

pub mod procs;
pub mod serve;
pub mod serve_dist;

pub use procs::{
    batch_digest, hetero_batch_digest, run_parent, run_worker, DistProcsConfig, DistProcsReport,
    WorkerConfig,
};
pub use serve::{InferenceServer, Prediction, ServeConfig, ServeStats};
pub use serve_dist::{
    run_traffic, DistInferenceServer, ServeDistConfig, ServeDistStats, TrafficConfig,
    TrafficReport,
};

use crate::error::Result;
use crate::loader::{Batch, LoaderConfig, NeighborLoader};
use crate::nn::ParamStore;
use crate::runtime::{EagerExecutor, Engine, Value};
use crate::storage::{FeatureStore, GraphStore};
use crate::tensor::argmax_rows;
use std::collections::HashMap;
use std::time::Instant;

/// Execution mode for the neural layer (the Tables 1-2 axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunMode {
    /// Op-by-op micro-op dispatch (PyTorch-eager analog).
    Eager,
    /// Single fused HLO (torch.compile analog).
    Compiled,
}

/// Training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub arch: String,
    pub mode: RunMode,
    pub trim: bool,
    pub epochs: usize,
    pub param_seed: u64,
    /// Log every n steps (0 = silent).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            arch: "gcn".into(),
            mode: RunMode::Compiled,
            trim: false,
            epochs: 3,
            param_seed: 7,
            log_every: 10,
        }
    }
}

/// Per-step record of the training history.
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub epoch: usize,
    pub step: usize,
    pub loss: f32,
    pub accuracy: f32,
    pub millis: f64,
}

/// Result of a training run.
#[derive(Debug)]
pub struct TrainReport {
    pub history: Vec<StepRecord>,
    pub final_params: ParamStore,
    pub mode: RunMode,
    pub total_seconds: f64,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        self.history.last().map(|r| r.loss).unwrap_or(f32::NAN)
    }

    /// Mean accuracy over the last `n` steps.
    pub fn recent_accuracy(&self, n: usize) -> f32 {
        let tail = &self.history[self.history.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().map(|r| r.accuracy).sum::<f32>() / tail.len() as f32
    }

    pub fn mean_step_ms(&self) -> f64 {
        if self.history.is_empty() {
            return f64::NAN;
        }
        self.history.iter().map(|r| r.millis).sum::<f64>() / self.history.len() as f64
    }
}

/// Program name for (arch, mode, trim) per the manifest naming scheme.
pub fn program_name(arch: &str, mode: RunMode, trim: bool) -> String {
    let base = match mode {
        RunMode::Eager => format!("{arch}_eager"),
        RunMode::Compiled => format!("{arch}_train"),
    };
    if trim {
        format!("{base}_trim")
    } else {
        base
    }
}

/// The trainer.
pub struct Trainer<'e> {
    engine: &'e Engine,
    cfg: TrainConfig,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: TrainConfig) -> Self {
        Self { engine, cfg }
    }

    /// Train over a loader; returns per-step history and final params.
    pub fn train<G, F>(&self, loader: &NeighborLoader<G, F>) -> Result<TrainReport>
    where
        G: GraphStore + 'static,
        F: FeatureStore + 'static,
    {
        let program = program_name(&self.cfg.arch, self.cfg.mode, self.cfg.trim);
        let mut store = ParamStore::init_for(self.engine.manifest(), &program, self.cfg.param_seed)?;
        let mut history = Vec::new();
        let t0 = Instant::now();

        match self.cfg.mode {
            RunMode::Compiled => {
                // Warm the executable cache outside the timed region.
                if let crate::runtime::Program::Fused { file, .. } =
                    self.engine.manifest().program(&program)?
                {
                    let file = file.clone();
                    self.engine.executable(&file)?;
                }
                let mut step_idx = 0;
                for epoch in 0..self.cfg.epochs {
                    for batch in loader.iter_epoch(epoch as u64) {
                        let batch = batch?;
                        let t = Instant::now();
                        let inputs = Engine::batch_inputs(&batch);
                        let out = self.engine.run_fused(&program, store.values_ref(), &inputs)?;
                        let millis = t.elapsed().as_secs_f64() * 1e3;
                        let loss = out[0].scalar_f32()?;
                        let accuracy = seed_accuracy(&out[1], &batch)?;
                        store.update_from_fused_output(&out)?;
                        self.log(epoch, step_idx, loss, accuracy);
                        history.push(StepRecord { epoch, step: step_idx, loss, accuracy, millis });
                        step_idx += 1;
                    }
                }
            }
            RunMode::Eager => {
                let exec = EagerExecutor::new(self.engine, &program)?;
                exec.warmup()?;
                let mut params: HashMap<String, Value> = store.as_map();
                let mut step_idx = 0;
                for epoch in 0..self.cfg.epochs {
                    for batch in loader.iter_epoch(epoch as u64) {
                        let batch = batch?;
                        let t = Instant::now();
                        let inputs = Engine::batch_inputs(&batch);
                        let (loss, logits) = exec.train_step(&mut params, &inputs)?;
                        let millis = t.elapsed().as_secs_f64() * 1e3;
                        let accuracy = seed_accuracy(&logits, &batch)?;
                        self.log(epoch, step_idx, loss, accuracy);
                        history.push(StepRecord { epoch, step: step_idx, loss, accuracy, millis });
                        step_idx += 1;
                    }
                }
                store.update_from_map(&params)?;
            }
        }

        Ok(TrainReport {
            history,
            final_params: store,
            mode: self.cfg.mode,
            total_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    fn log(&self, epoch: usize, step: usize, loss: f32, acc: f32) {
        if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
            log::info!(
                "epoch {epoch} step {step}: loss={loss:.4} acc={acc:.3} ({:?} {})",
                self.cfg.mode,
                self.cfg.arch
            );
        }
    }
}

/// Seed-level accuracy from a logits value `[S, C]`.
pub fn seed_accuracy(logits: &Value, batch: &Batch) -> Result<f32> {
    let t = logits.to_tensor()?;
    let preds = argmax_rows(&t);
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..batch.num_real_seeds() {
        if batch.labels[i] >= 0 {
            total += 1;
            if preds[i] as i32 == batch.labels[i] {
                correct += 1;
            }
        }
    }
    Ok(if total == 0 { 0.0 } else { correct as f32 / total as f32 })
}

/// Convenience: a loader matching the manifest's default bucket over an
/// SBM graph (the quickstart / bench workload).
pub fn default_loader(
    engine: &Engine,
    graph: &crate::graph::Graph,
    seeds: Vec<u32>,
    num_workers: usize,
) -> NeighborLoader<crate::storage::InMemoryGraphStore, crate::storage::InMemoryFeatureStore> {
    let bucket = engine.manifest().bucket.clone();
    let gs = std::sync::Arc::new(crate::storage::InMemoryGraphStore::from_graph(graph));
    let fs = std::sync::Arc::new(crate::storage::InMemoryFeatureStore::from_tensor(graph.x.clone()));
    let mut loader = NeighborLoader::new(
        gs,
        fs,
        seeds,
        LoaderConfig {
            batch_size: bucket.s,
            num_workers,
            shuffle: true,
            sampler: crate::sampler::NeighborSamplerConfig {
                fanouts: bucket.fanouts.clone(),
                ..Default::default()
            },
            bucket: Some(bucket.to_shape_bucket()),
            ..Default::default()
        },
    );
    if let Some(y) = &graph.y {
        loader = loader.with_labels(y.clone());
    }
    loader
}

/// Optional layers of the distributed pipeline (PR 2): halo caching and
/// async routing, plus the simulated per-RPC latency they hide.
#[derive(Clone, Copy, Debug, Default)]
pub struct DistOptions {
    /// Pre-replicate `Partitioning::halo_nodes` feature rows on the
    /// local rank and serve them without an RPC.
    pub halo_cache: bool,
    /// Serve remote feature fetch plans on an
    /// [`crate::dist::AsyncRouter`] pool, overlapping per-partition RPCs
    /// with sampling.
    pub async_fetch: bool,
    /// Worker threads of the async fetch pool (0 = one per remote
    /// partition).
    pub async_workers: usize,
    /// Simulated network round trip charged per coalesced remote
    /// *feature* RPC (the payload-heavy path; sampler adjacency reads
    /// are accounted as messages but pay no simulated latency).
    pub latency: std::time::Duration,
    /// Pipeline prefetch on mounted bundles (`--prefetch`): warm batch
    /// k+1's seed rows and in-edge lists through a
    /// [`crate::dist::MountPrefetcher`] while batch k computes. Cache
    /// warming only — batch content is seed-for-seed unchanged
    /// (`tests/test_prefetch_pipeline.rs`). Ignored by the in-memory
    /// (non-mounted) pipelines, which have no disk to hide.
    pub prefetch: bool,
    /// Positioned-I/O backend for mounted shard files
    /// (`--io-backend pread|mmap`); see [`crate::persist::IoBackend`].
    pub io_backend: crate::persist::IoBackend,
    /// Adjacency halo replication on paged mounts (`--halo-adj`):
    /// pin the in-edge lists (and edge timestamps) of the rank's halo
    /// nodes under the [`crate::persist::LruConfig::halo_budget`] share,
    /// spilling what the share cannot hold into the ordinary LRU — see
    /// [`crate::dist::PartitionedGraphStore::build_adj_halo`]. A no-op
    /// on resident topologies and in-memory pipelines (their in-lists
    /// are already local). Batch content is seed-for-seed unchanged.
    pub halo_adj: bool,
}

/// The partitioned serving path (§2.3): wire a graph through the full
/// distributed stack — one shared [`crate::dist::PartitionRouter`],
/// partitioned feature + graph stores, and a
/// [`crate::dist::DistNeighborLoader`] — viewed from `local_rank`.
///
/// With the same [`LoaderConfig`] this yields batches identical to the
/// single-store loader; the returned loader's `router_stats()` report the
/// cross-partition traffic the partitioning saved or cost.
pub fn partitioned_loader(
    graph: &crate::graph::Graph,
    partitioning: &crate::partition::Partitioning,
    local_rank: u32,
    seeds: Vec<u32>,
    cfg: LoaderConfig,
) -> Result<crate::dist::DistNeighborLoader> {
    partitioned_loader_with(graph, partitioning, local_rank, seeds, cfg, DistOptions::default())
}

/// [`partitioned_loader`] with the halo-cache / async-routing layers of
/// [`DistOptions`]. Neither layer changes batch content (enforced by
/// `tests/test_dist_equivalence.rs`); they change what the epoch *costs*:
/// cached halo rows ship no RPC, async plans overlap the RPCs that
/// remain.
pub fn partitioned_loader_with(
    graph: &crate::graph::Graph,
    partitioning: &crate::partition::Partitioning,
    local_rank: u32,
    seeds: Vec<u32>,
    cfg: LoaderConfig,
    opts: DistOptions,
) -> Result<crate::dist::DistNeighborLoader> {
    build_partitioned_loader(graph, partitioning, local_rank, seeds, cfg, opts, None)
}

/// Assemble the in-memory partitioned store pair viewed from
/// `local_rank` — one shared [`crate::dist::PartitionRouter`], a
/// [`crate::dist::PartitionedGraphStore`] over the edge shards, and a
/// [`crate::dist::PartitionedFeatureStore`] with the
/// [`DistOptions`] layers (halo replica / async router / simulated
/// latency) applied — without committing to a consumer. Both the epoch
/// loaders ([`partitioned_loader_with`]) and the serving path
/// ([`crate::coordinator::DistInferenceServer`]) build on this.
pub fn partitioned_stores(
    graph: &crate::graph::Graph,
    partitioning: &crate::partition::Partitioning,
    local_rank: u32,
    opts: DistOptions,
) -> Result<(
    std::sync::Arc<crate::dist::PartitionedGraphStore>,
    std::sync::Arc<crate::dist::PartitionedFeatureStore>,
)> {
    build_partitioned_stores(graph, partitioning, local_rank, opts, None)
}

/// Shared store builder: `halo` overrides the cache's node list when the
/// caller already computed it (the multi-rank simulation sweeps every
/// partition's halo once via [`crate::partition::Partitioning::halos`]
/// instead of re-scanning the edge list per rank).
fn build_partitioned_stores(
    graph: &crate::graph::Graph,
    partitioning: &crate::partition::Partitioning,
    local_rank: u32,
    opts: DistOptions,
    halo: Option<&[u32]>,
) -> Result<(
    std::sync::Arc<crate::dist::PartitionedGraphStore>,
    std::sync::Arc<crate::dist::PartitionedFeatureStore>,
)> {
    use crate::dist::{
        AsyncRouter, HaloCache, PartitionRouter, PartitionedFeatureStore, PartitionedGraphStore,
    };
    use std::sync::Arc;

    let router = Arc::new(PartitionRouter::new(partitioning, local_rank)?);
    let gs = Arc::new(PartitionedGraphStore::from_graph(graph, Arc::clone(&router))?);
    let src_features = crate::storage::InMemoryFeatureStore::from_tensor(graph.x.clone());
    let mut fs = PartitionedFeatureStore::partition(&src_features, router)?
        .with_latency(opts.latency);
    if opts.halo_cache {
        let computed;
        let halo = match halo {
            Some(h) => h,
            None => {
                computed = partitioning.halo_nodes(&graph.edge_index, local_rank);
                computed.as_slice()
            }
        };
        let cache = HaloCache::build(halo, &src_features, graph.num_nodes(), local_rank)?;
        fs = fs.with_halo_cache(Arc::new(cache))?;
    }
    if opts.async_fetch {
        let workers = if opts.async_workers > 0 {
            opts.async_workers
        } else {
            partitioning.num_parts.saturating_sub(1).max(1)
        };
        fs = fs.with_async_router(Arc::new(AsyncRouter::new(workers)));
    }
    Ok((gs, Arc::new(fs)))
}

/// Shared loader builder over [`build_partitioned_stores`].
fn build_partitioned_loader(
    graph: &crate::graph::Graph,
    partitioning: &crate::partition::Partitioning,
    local_rank: u32,
    seeds: Vec<u32>,
    cfg: LoaderConfig,
    opts: DistOptions,
    halo: Option<&[u32]>,
) -> Result<crate::dist::DistNeighborLoader> {
    let (gs, fs) = build_partitioned_stores(graph, partitioning, local_rank, opts, halo)?;
    let mut loader = crate::dist::DistNeighborLoader::new(gs, fs, seeds, cfg);
    if let Some(y) = &graph.y {
        loader = loader.with_labels(y.clone());
    }
    Ok(loader)
}

/// Per-rank wall-clock summary of a multi-rank simulation: today the
/// ranks run sequentially for determinism (see ROADMAP "truly parallel
/// ranks"), so the *skew* — how unevenly the per-rank epoch times would
/// load a real cluster — is the early signal this reports alongside the
/// [`crate::dist::TrafficMatrix`].
#[derive(Clone, Copy, Debug)]
pub struct RankSkew {
    pub min: f64,
    pub max: f64,
    pub mean: f64,
}

impl RankSkew {
    pub fn from_seconds(secs: &[f64]) -> Self {
        if secs.is_empty() {
            return Self { min: 0.0, max: 0.0, mean: 0.0 };
        }
        let min = secs.iter().copied().fold(f64::INFINITY, f64::min);
        let max = secs.iter().copied().fold(0.0f64, f64::max);
        let mean = secs.iter().sum::<f64>() / secs.len() as f64;
        Self { min, max, mean }
    }

    /// `max / min` ratio (1.0 = perfectly balanced ranks; the slowest
    /// rank gates a synchronous cluster).
    pub fn imbalance(&self) -> f64 {
        if self.min > 0.0 {
            self.max / self.min
        } else {
            1.0
        }
    }
}

impl std::fmt::Display for RankSkew {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "per-rank epoch wall-clock: min {:.3}s / mean {:.3}s / max {:.3}s ({:.2}x max/min)",
            self.min,
            self.mean,
            self.max,
            self.imbalance()
        )
    }
}

/// Mirror one rank's epoch wall-clock into the registry (gauge
/// `dist.rank{r}.epoch_us`, last run wins) so `--metrics-out` exports
/// the same per-rank timings the reports' [`RankSkew`] summarizes. The
/// reports keep their own `rank_seconds` vector — gauges are global and
/// a concurrent simulation (e.g. parallel tests) would stomp them, so
/// `skew()` must stay a view over the report-local measurements.
pub(crate) fn record_rank_epoch(rank: u32, secs: f64) {
    crate::obs::gauge(&format!("dist.rank{rank}.epoch_us")).set((secs * 1e6) as i64);
}

/// Result of a [`multi_rank_epoch`] simulation: the `rank × partition`
/// traffic matrix plus per-rank cache counters, wall-clock, and epoch
/// totals.
#[derive(Debug)]
pub struct MultiRankReport {
    pub matrix: crate::dist::TrafficMatrix,
    /// Per-rank halo-cache counters (`None` when caching was off).
    pub cache: Vec<Option<crate::dist::CacheStats>>,
    /// Per-partition `(in_edges, out_edges)` shard sizes — the storage
    /// side of the simulation (identical from every rank's view).
    pub shard_edges: Vec<(usize, usize)>,
    /// Wall-clock seconds each rank spent on its epochs (ranks run
    /// sequentially; see [`RankSkew`]).
    pub rank_seconds: Vec<f64>,
    pub batches: usize,
    pub sampled_nodes: usize,
}

impl MultiRankReport {
    /// Min/max/mean of [`MultiRankReport::rank_seconds`].
    pub fn skew(&self) -> RankSkew {
        RankSkew::from_seconds(&self.rank_seconds)
    }
}

/// Multi-rank simulation: one [`crate::dist::DistNeighborLoader`] per
/// rank over that rank's *own* seed shard (the nodes its partition
/// owns — the realistic distributed workload, where partition quality
/// keeps sampling local), each viewing the cluster from its rank. Runs
/// `epochs` epochs per rank and aggregates every router's
/// per-destination counters into a [`crate::dist::TrafficMatrix`].
///
/// `ranks` must not exceed `partitioning.num_parts` (pass
/// `partitioning.num_parts` for the full cluster; fewer simulates a
/// partially deployed one).
pub fn multi_rank_epoch(
    graph: &crate::graph::Graph,
    partitioning: &crate::partition::Partitioning,
    ranks: usize,
    cfg: &LoaderConfig,
    opts: DistOptions,
    epochs: u64,
) -> Result<MultiRankReport> {
    use crate::error::Error;

    if ranks == 0 || ranks > partitioning.num_parts {
        return Err(Error::Config(format!(
            "{ranks} ranks over {} partitions (need 1..=num_parts)",
            partitioning.num_parts
        )));
    }
    let mut matrix = crate::dist::TrafficMatrix::new(ranks, partitioning.num_parts);
    let mut cache = Vec::with_capacity(ranks);
    let mut shard_edges = Vec::new();
    let mut rank_seconds = Vec::with_capacity(ranks);
    let mut batches = 0usize;
    let mut sampled_nodes = 0usize;
    // One edge sweep computes every rank's halo (vs one sweep per rank).
    let halos = if opts.halo_cache {
        Some(partitioning.halos(&graph.edge_index))
    } else {
        None
    };
    for rank in 0..ranks as u32 {
        let seeds = partitioning.nodes_of(rank);
        let loader = build_partitioned_loader(
            graph,
            partitioning,
            rank,
            seeds,
            cfg.clone(),
            opts,
            halos.as_ref().map(|h| h[rank as usize].as_slice()),
        )?;
        let t_rank = Instant::now();
        for epoch in 0..epochs {
            for batch in loader.iter_epoch(epoch) {
                let b = batch?;
                batches += 1;
                sampled_nodes += b.num_real_nodes();
            }
        }
        let rank_secs = t_rank.elapsed().as_secs_f64();
        record_rank_epoch(rank, rank_secs);
        rank_seconds.push(rank_secs);
        matrix.set_rank(rank as usize, &loader.graph().router().traffic_by_partition())?;
        cache.push(loader.cache_stats());
        if rank == 0 {
            shard_edges = loader.graph().shard_edge_counts();
        }
    }
    Ok(MultiRankReport { matrix, cache, shard_edges, rank_seconds, batches, sampled_nodes })
}

/// Wire a heterogeneous graph through the full typed distributed stack —
/// one shared [`crate::dist::TypedRouter`], per-type partitioned feature
/// + graph stores, and a [`crate::dist::HeteroDistNeighborLoader`] —
/// viewed from `local_rank`, seeding on `seed_type`.
///
/// With the same [`crate::loader::HeteroLoaderConfig`] this yields
/// batches identical to the in-memory
/// [`crate::loader::HeteroNeighborLoader`]; the returned loader's
/// `router_stats()` / `edge_traffic()` report the cross-partition
/// traffic per node type and per relation.
pub fn hetero_partitioned_loader(
    graph: &crate::graph::HeteroGraph,
    partitioning: &crate::partition::TypedPartitioning,
    local_rank: u32,
    seed_type: &str,
    seeds: Vec<u32>,
    cfg: crate::loader::HeteroLoaderConfig,
) -> Result<crate::dist::HeteroDistNeighborLoader> {
    hetero_partitioned_loader_with(
        graph,
        partitioning,
        local_rank,
        seed_type,
        seeds,
        cfg,
        DistOptions::default(),
    )
}

/// [`hetero_partitioned_loader`] with the halo-cache / async-routing
/// layers of [`DistOptions`]: per-node-type halo replicas
/// ([`crate::partition::TypedPartitioning::halo_nodes`]) filter the
/// remote feature path, an [`crate::dist::AsyncRouter`] overlaps the
/// RPCs that remain. Neither layer changes batch content (enforced by
/// `tests/test_dist_hetero_equivalence.rs`).
pub fn hetero_partitioned_loader_with(
    graph: &crate::graph::HeteroGraph,
    partitioning: &crate::partition::TypedPartitioning,
    local_rank: u32,
    seed_type: &str,
    seeds: Vec<u32>,
    cfg: crate::loader::HeteroLoaderConfig,
    opts: DistOptions,
) -> Result<crate::dist::HeteroDistNeighborLoader> {
    build_hetero_partitioned_loader(
        graph,
        partitioning,
        local_rank,
        seed_type,
        seeds,
        cfg,
        opts,
        None,
    )
}

/// Shared typed builder: `halos` overrides the per-type halo node lists
/// when the caller already computed them (the multi-rank simulation
/// sweeps every `(type, partition)` halo once via
/// [`crate::partition::TypedPartitioning::halos`] instead of re-scanning
/// the edge lists per rank).
#[allow(clippy::too_many_arguments)]
fn build_hetero_partitioned_loader(
    graph: &crate::graph::HeteroGraph,
    partitioning: &crate::partition::TypedPartitioning,
    local_rank: u32,
    seed_type: &str,
    seeds: Vec<u32>,
    cfg: crate::loader::HeteroLoaderConfig,
    opts: DistOptions,
    halos: Option<&std::collections::BTreeMap<String, Vec<Vec<u32>>>>,
) -> Result<crate::dist::HeteroDistNeighborLoader> {
    use crate::dist::{
        AsyncRouter, HaloCache, HeteroDistNeighborLoader, PartitionedFeatureStore,
        PartitionedGraphStore, TypedRouter,
    };
    use crate::storage::{FeatureKey, DEFAULT_ATTR};
    use std::collections::BTreeMap;
    use std::sync::Arc;

    let router = TypedRouter::new(partitioning, local_rank)?;
    let gs = Arc::new(PartitionedGraphStore::from_hetero(graph, router.clone())?);
    let mut fs =
        PartitionedFeatureStore::partition_hetero(graph, &router)?.with_latency(opts.latency);
    if opts.halo_cache {
        let mut caches = BTreeMap::new();
        for nt in graph.node_types() {
            // The multi-rank simulation hands in the all-ranks sweep;
            // the single-rank path computes only its own rank's typed
            // halo per type.
            let computed;
            let halo: &[u32] = match halos {
                Some(h) => &h[nt][local_rank as usize],
                None => {
                    computed = partitioning.halo_nodes(graph, nt, local_rank)?;
                    &computed
                }
            };
            // Gather only the halo rows (straight off the graph's
            // tensor, the same one the shards were cut from) — no full
            // per-type source store materialized per rank.
            let idx: Vec<usize> = halo.iter().map(|&v| v as usize).collect();
            let rows = graph.node_store(nt)?.x.gather_rows(&idx)?;
            caches.insert(
                nt.to_string(),
                Arc::new(HaloCache::from_group(
                    FeatureKey::new(nt, DEFAULT_ATTR),
                    halo,
                    rows,
                    graph.num_nodes(nt)?,
                    local_rank,
                )?),
            );
        }
        fs = fs.with_halo_caches(caches)?;
    }
    if opts.async_fetch {
        let workers = if opts.async_workers > 0 {
            opts.async_workers
        } else {
            partitioning.num_parts.saturating_sub(1).max(1)
        };
        fs = fs.with_async_router(Arc::new(AsyncRouter::new(workers)));
    }
    let mut loader = HeteroDistNeighborLoader::new(gs, Arc::new(fs), seed_type, seeds, cfg);
    if let Some(y) = &graph.node_store(seed_type)?.y {
        loader = loader.with_labels(y.clone());
    }
    Ok(loader)
}

/// Wire a mounted [`crate::persist::Bundle`] through the full
/// out-of-core distributed stack, viewed from `local_rank`: the
/// topology comes from the bundle's binary adjacency shards — decoded
/// at mount ([`crate::dist::PartitionedGraphStore::mount`]) or, with
/// `lru.page_adjacency`, demand-paged per neighbor list through the
/// bounded adjacency cache
/// ([`crate::dist::PartitionedGraphStore::mount_paged`], sharing the
/// mount's byte budget) — feature rows are demand-paged from its
/// `.pygf` shards through the bounded LRU
/// ([`crate::dist::PartitionedFeatureStore::mount_with_router`], budget
/// from `lru`), and labels come from the bundle. Yields batches
/// identical to [`partitioned_loader_with`] over the original graph
/// under the same [`LoaderConfig`] (`tests/test_persist_equivalence.rs`).
///
/// The [`DistOptions`] layers compose unchanged: a halo replica (built
/// by reading the halo rows *from the mounted shard files* through a
/// cache/latency/counter-free raw view, so it is byte-identical to
/// routed fetches without polluting the row cache) filters the remote
/// path before the LRU ever sees a request, and an async router
/// overlaps what remains. Construction costs nothing on the loader's
/// ledgers: traffic counters, cache stats and disk reads all start at
/// zero.
pub fn mounted_loader(
    bundle: &crate::persist::Bundle,
    local_rank: u32,
    seeds: Vec<u32>,
    cfg: LoaderConfig,
    opts: DistOptions,
    lru: crate::persist::LruConfig,
) -> Result<crate::dist::DistNeighborLoader> {
    mounted_loader_with_transport(bundle, local_rank, seeds, cfg, opts, lru, None)
}

/// [`mounted_loader`] with an optional real RPC [`crate::dist::Transport`]
/// installed on the feature store's remote path — how `pyg2 dist-worker`
/// ranks fetch foreign rows from their peers instead of their own local
/// shard replicas.
pub fn mounted_loader_with_transport(
    bundle: &crate::persist::Bundle,
    local_rank: u32,
    seeds: Vec<u32>,
    cfg: LoaderConfig,
    opts: DistOptions,
    lru: crate::persist::LruConfig,
    transport: Option<std::sync::Arc<dyn crate::dist::Transport>>,
) -> Result<crate::dist::DistNeighborLoader> {
    let (gs, fs, labels) = mounted_stores_with_transport(bundle, local_rank, opts, lru, transport)?;
    let mut loader = crate::dist::DistNeighborLoader::new(
        std::sync::Arc::clone(&gs),
        std::sync::Arc::clone(&fs),
        seeds,
        cfg,
    );
    if opts.prefetch {
        loader = loader.with_prefetcher(std::sync::Arc::new(
            crate::dist::MountPrefetcher::new(gs, fs, crate::storage::DEFAULT_GROUP),
        ));
    }
    if let Some(y) = labels {
        loader = loader.with_labels(y);
    }
    Ok(loader)
}

/// Mount a homogeneous bundle into the partitioned store pair viewed
/// from `local_rank` (adjacency resident or demand-paged per
/// `lru.page_adjacency`; feature rows demand-paged through the bounded
/// LRU), with the [`DistOptions`] layers applied, plus the bundle's
/// labels if stored. The consumer-neutral half of [`mounted_loader`],
/// which the distributed inference server mounts its serving stores
/// through. I/O ledgers (traffic, cache, disk-read counters) are zeroed
/// after setup so they report workload costs only.
pub fn mounted_stores(
    bundle: &crate::persist::Bundle,
    local_rank: u32,
    opts: DistOptions,
    lru: crate::persist::LruConfig,
) -> Result<(
    std::sync::Arc<crate::dist::PartitionedGraphStore>,
    std::sync::Arc<crate::dist::PartitionedFeatureStore>,
    Option<Vec<i64>>,
)> {
    mounted_stores_with_transport(bundle, local_rank, opts, lru, None)
}

/// [`mounted_stores`] with an optional real RPC
/// [`crate::dist::Transport`] on the feature store's remote path.
pub fn mounted_stores_with_transport(
    bundle: &crate::persist::Bundle,
    local_rank: u32,
    opts: DistOptions,
    lru: crate::persist::LruConfig,
    transport: Option<std::sync::Arc<dyn crate::dist::Transport>>,
) -> Result<(
    std::sync::Arc<crate::dist::PartitionedGraphStore>,
    std::sync::Arc<crate::dist::PartitionedFeatureStore>,
    Option<Vec<i64>>,
)> {
    use crate::dist::{AsyncRouter, HaloCache, PartitionedFeatureStore};
    use crate::error::Error;
    use crate::storage::DEFAULT_GROUP;
    use std::sync::Arc;

    if bundle.is_typed() {
        return Err(Error::Config(
            "bundle is typed (heterogeneous): use hetero_mounted_loader".into(),
        ));
    }
    // `--halo-adj` carves the halo tier's share out of the budget;
    // either the option or a pre-configured LruConfig activates it.
    let mut lru = lru;
    lru.halo_adj = lru.halo_adj || opts.halo_adj;
    lru.validate()?;
    let gs = Arc::new(mount_graph_store(bundle, local_rank, lru, opts.io_backend)?);
    // Adjacency halo replication: pin the hottest halo in-lists under
    // the budget's halo share before the epoch starts (spilling the
    // rest into the AdjCache LRU); None on resident topologies.
    let adj_halo = if lru.halo_budget() > 0 {
        gs.build_adj_halo(lru.halo_budget())?
    } else {
        None
    };
    let mut fs = PartitionedFeatureStore::mount_with_router_backend(
        bundle,
        gs.typed_router().clone(),
        lru,
        opts.io_backend,
    )?
    .with_latency(opts.latency);
    if opts.halo_cache {
        let n = bundle.node_type(DEFAULT_GROUP)?.num_nodes;
        // Under an active halo share (--halo-adj on a paged mount) the
        // feature replica is bounded by whatever the pinned adjacency
        // tier left of it: same ranking (partition-time cut-edge
        // counts), same strict-prefix policy — so the two halo tiers
        // jointly stay inside one share of the `--cache-mb` ceiling.
        // Rows the share cannot hold are warmed into the ordinary
        // bounded RowCache below instead of pinned. Without a halo
        // share the replica stays complete (the documented
        // `--halo-cache`-only behaviour).
        let (halo, spilled) = match &adj_halo {
            Some(tier) => {
                let remaining = lru.halo_budget().saturating_sub(tier.pinned_bytes);
                let raw = fs.raw_reader().ok_or_else(|| {
                    Error::Mount("halo ranking needs a mounted store's raw view".into())
                })?;
                let mut row_bytes = 0u64;
                for key in raw.keys() {
                    row_bytes += raw.feature_dim(&key)? as u64 * 4;
                }
                let mut ranked = gs
                    .halos_ranked()?
                    .remove(DEFAULT_GROUP)
                    .unwrap_or_default();
                ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                let (mut kept, mut spill) = (Vec::new(), Vec::new());
                let (mut used, mut pinning) = (0u64, true);
                for (v, _) in ranked {
                    if pinning && used + row_bytes > remaining {
                        pinning = false;
                    }
                    if pinning {
                        used += row_bytes;
                        kept.push(v);
                    } else {
                        spill.push(v);
                    }
                }
                // The HaloCache contract wants ascending node ids.
                kept.sort_unstable();
                (kept, spill)
            }
            None => (gs.halo_nodes(DEFAULT_GROUP)?, Vec::new()),
        };
        // Build the replica through the raw (cache/latency/counter-free)
        // view: halo rows are intercepted by the replica forever after,
        // so inserting them into the bounded row cache would only evict
        // capacity from rows that can still miss.
        let cache = {
            let raw = fs.raw_reader().ok_or_else(|| {
                Error::Mount("halo replica construction needs a mounted store's raw view".into())
            })?;
            HaloCache::build(&halo, &raw, n, local_rank)?
        };
        fs = fs.with_halo_cache(Arc::new(cache))?;
        if !spilled.is_empty() {
            // Spilled halo rows seed the ordinary bounded RowCache (a
            // prefetch-tagged warm the LRU is free to evict).
            fs.prefetch_rows(DEFAULT_GROUP, &spilled)?;
        }
    }
    if opts.async_fetch {
        let workers = if opts.async_workers > 0 {
            opts.async_workers
        } else {
            bundle.num_parts().saturating_sub(1).max(1)
        };
        fs = fs.with_async_router(Arc::new(AsyncRouter::new(workers)));
    }
    if let Some(t) = transport {
        fs = fs.with_transport(t);
    }
    let labels = bundle.load_labels(DEFAULT_GROUP)?;
    // Replica construction read its rows off disk (bypassing the row
    // cache); zero the I/O ledgers so they report workload costs only.
    // (Paged-adjacency setup streams shards through uncounted reads,
    // but reset its ledgers too so both halves start from zero.)
    let fs = Arc::new(fs);
    fs.reset_io_stats();
    gs.reset_adj_io_stats();
    Ok((gs, fs, labels))
}

/// Mount a bundle's topology honouring the [`crate::persist::LruConfig`]
/// paging mode: resident decode, or demand-paged shards behind a fresh
/// [`crate::persist::AdjCache`] sized to the budget's adjacency share.
fn mount_graph_store(
    bundle: &crate::persist::Bundle,
    local_rank: u32,
    lru: crate::persist::LruConfig,
    backend: crate::persist::IoBackend,
) -> Result<crate::dist::PartitionedGraphStore> {
    use std::sync::Arc;
    if lru.page_adjacency {
        let cache = Arc::new(crate::persist::AdjCache::new(lru.adj_budget()));
        crate::dist::PartitionedGraphStore::mount_paged_with(bundle, local_rank, cache, backend)
    } else {
        // Resident decode reads each shard once at mount; the backend
        // knob only matters for the demand-paged readers.
        crate::dist::PartitionedGraphStore::mount(bundle, local_rank)
    }
}

/// The typed counterpart of [`mounted_loader`]: mount a heterogeneous
/// bundle and drive the [`crate::dist::HeteroDistNeighborLoader`] over
/// it, seeding on `seed_type` (adjacency resident or demand-paged per
/// `lru.page_adjacency`, exactly as in [`mounted_loader`]).
/// Homogeneous bundles work too (their one `_default` type is the
/// single-type special case). Batch content is identical to
/// [`hetero_partitioned_loader_with`] over the original graph
/// (`tests/test_persist_equivalence.rs`).
pub fn hetero_mounted_loader(
    bundle: &crate::persist::Bundle,
    local_rank: u32,
    seed_type: &str,
    seeds: Vec<u32>,
    cfg: crate::loader::HeteroLoaderConfig,
    opts: DistOptions,
    lru: crate::persist::LruConfig,
) -> Result<crate::dist::HeteroDistNeighborLoader> {
    hetero_mounted_loader_with_transport(bundle, local_rank, seed_type, seeds, cfg, opts, lru, None)
}

/// [`hetero_mounted_loader`] with an optional real RPC
/// [`crate::dist::Transport`] on the typed feature store's remote path.
#[allow(clippy::too_many_arguments)]
pub fn hetero_mounted_loader_with_transport(
    bundle: &crate::persist::Bundle,
    local_rank: u32,
    seed_type: &str,
    seeds: Vec<u32>,
    cfg: crate::loader::HeteroLoaderConfig,
    opts: DistOptions,
    lru: crate::persist::LruConfig,
    transport: Option<std::sync::Arc<dyn crate::dist::Transport>>,
) -> Result<crate::dist::HeteroDistNeighborLoader> {
    use crate::dist::{AsyncRouter, HaloCache, HeteroDistNeighborLoader, PartitionedFeatureStore};
    use crate::storage::{FeatureKey, FeatureStore, DEFAULT_ATTR};
    use std::collections::BTreeMap;
    use std::sync::Arc;

    bundle.node_type(seed_type)?; // validate the seed type early
    // `--halo-adj` carves the halo tier's share out of the budget;
    // either the option or a pre-configured LruConfig activates it.
    let mut lru = lru;
    lru.halo_adj = lru.halo_adj || opts.halo_adj;
    lru.validate()?;
    let gs = Arc::new(mount_graph_store(bundle, local_rank, lru, opts.io_backend)?);
    // Adjacency halo replication: pin the hottest halo in-lists, per
    // (edge type, rank), under the budget's halo share before the
    // epoch starts (spilling the rest into the AdjCache LRU); None on
    // resident topologies.
    let adj_halo = if lru.halo_budget() > 0 {
        gs.build_adj_halo(lru.halo_budget())?
    } else {
        None
    };
    let mut fs = PartitionedFeatureStore::mount_with_router_backend(
        bundle,
        gs.typed_router().clone(),
        lru,
        opts.io_backend,
    )?
    .with_latency(opts.latency);
    if opts.halo_cache {
        let mut caches = BTreeMap::new();
        // One edge sweep computes every node type's halo with its
        // cut-edge counts (on a paged mount this streams each shard
        // file once, not once per adjacent type).
        let ranked = gs.halos_ranked()?;
        // Under an active halo share (--halo-adj on a paged mount) the
        // typed feature replicas are bounded by what the pinned
        // adjacency tier left of it: one global ranking across node
        // types by cut-edge count, same strict-prefix policy, so both
        // halo tiers jointly stay inside one share of the `--cache-mb`
        // ceiling. Rows the share cannot hold are warmed into the
        // ordinary bounded RowCache after the replicas install.
        let mut spilled: BTreeMap<String, Vec<u32>> = BTreeMap::new();
        let halos: BTreeMap<String, Vec<u32>> = match &adj_halo {
            Some(tier) => {
                let remaining = lru.halo_budget().saturating_sub(tier.pinned_bytes);
                let raw = fs.raw_reader().ok_or_else(|| {
                    crate::error::Error::Mount(
                        "typed halo ranking needs a mounted store's raw view".into(),
                    )
                })?;
                let mut row_bytes = BTreeMap::new();
                let mut cands = Vec::new();
                for nt in &bundle.manifest().node_types {
                    let key = FeatureKey::new(&nt.name, DEFAULT_ATTR);
                    row_bytes.insert(nt.name.clone(), raw.feature_dim(&key)? as u64 * 4);
                    for &(v, count) in &ranked[&nt.name] {
                        cands.push((count, nt.name.as_str(), v));
                    }
                }
                cands.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(b.1)).then(a.2.cmp(&b.2)));
                let mut kept: BTreeMap<String, Vec<u32>> = bundle
                    .manifest()
                    .node_types
                    .iter()
                    .map(|nt| (nt.name.clone(), Vec::new()))
                    .collect();
                let (mut used, mut pinning) = (0u64, true);
                for (_, nt, v) in cands {
                    let bytes = row_bytes[nt];
                    if pinning && used + bytes > remaining {
                        pinning = false;
                    }
                    if pinning {
                        used += bytes;
                        kept.get_mut(nt).expect("manifest type").push(v);
                    } else {
                        spilled.entry(nt.to_string()).or_default().push(v);
                    }
                }
                // The HaloCache contract wants ascending node ids.
                for l in kept.values_mut() {
                    l.sort_unstable();
                }
                kept
            }
            None => ranked
                .into_iter()
                .map(|(nt, r)| (nt, r.into_iter().map(|(v, _)| v).collect()))
                .collect(),
        };
        for nt in &bundle.manifest().node_types {
            // Gather the typed halo rows straight off the shard files
            // (cache/latency/counter-free raw view) — the same bytes a
            // routed fetch would return, so hits stay bit-identical to
            // the uncached path, without polluting the bounded row
            // cache with rows the replica will intercept forever after.
            let halo = &halos[&nt.name];
            let idx: Vec<usize> = halo.iter().map(|&v| v as usize).collect();
            let key = FeatureKey::new(&nt.name, DEFAULT_ATTR);
            let rows = fs
                .raw_reader()
                .ok_or_else(|| {
                    crate::error::Error::Mount(
                        "typed halo replica construction needs a mounted store's raw view".into(),
                    )
                })?
                .get(&key, &idx)?;
            caches.insert(
                nt.name.clone(),
                Arc::new(HaloCache::from_group(key, halo, rows, nt.num_nodes, local_rank)?),
            );
        }
        fs = fs.with_halo_caches(caches)?;
        for (nt, nodes) in &spilled {
            // Spilled halo rows seed the ordinary bounded RowCache (a
            // prefetch-tagged warm the LRU is free to evict).
            fs.prefetch_rows(nt, nodes)?;
        }
    }
    if opts.async_fetch {
        let workers = if opts.async_workers > 0 {
            opts.async_workers
        } else {
            bundle.num_parts().saturating_sub(1).max(1)
        };
        fs = fs.with_async_router(Arc::new(AsyncRouter::new(workers)));
    }
    if let Some(t) = transport {
        fs = fs.with_transport(t);
    }
    let fs = Arc::new(fs);
    let mut loader = HeteroDistNeighborLoader::new(
        Arc::clone(&gs),
        Arc::clone(&fs),
        seed_type,
        seeds,
        cfg,
    );
    if opts.prefetch {
        loader = loader.with_prefetcher(Arc::new(crate::dist::MountPrefetcher::new(
            gs, fs, seed_type,
        )));
    }
    if let Some(y) = bundle.load_labels(seed_type)? {
        loader = loader.with_labels(y);
    }
    // Replica construction read its rows off disk (bypassing the row
    // cache); zero the I/O ledgers so they report epoch costs only.
    loader.features().reset_io_stats();
    loader.graph().reset_adj_io_stats();
    Ok(loader)
}

/// Result of a [`multi_rank_epoch_mounted`] simulation: the
/// `rank × partition` traffic matrix plus, per rank, the halo-cache
/// counters, the bounded row cache's hit/miss/evict/byte counters, the
/// positioned disk reads its misses cost, and wall-clock.
#[derive(Debug)]
pub struct MountedMultiRankReport {
    pub matrix: crate::dist::TrafficMatrix,
    /// Per-rank halo-cache counters (`None` when caching was off).
    pub halo: Vec<Option<crate::dist::CacheStats>>,
    /// Per-rank bounded-LRU row cache counters.
    pub row_cache: Vec<crate::persist::RowCacheStats>,
    /// Per-rank adjacency block cache counters (`None` unless the
    /// mount pages adjacency — `--page-adj`). Together with
    /// `row_cache` this is the [`crate::persist::MountCacheStats`]
    /// split of the shared budget.
    pub adj_cache: Vec<Option<crate::persist::RowCacheStats>>,
    /// Per-rank adjacency halo tier counters (`None` unless the mount
    /// replicated halo in-lists — `--halo-adj` with `--page-adj`): the
    /// pinned third of the [`crate::persist::MountCacheStats`] split.
    pub adj_halo: Vec<Option<crate::persist::HaloTierStats>>,
    /// Per-rank positioned disk reads over the bundle's feature shards.
    pub disk_reads: Vec<u64>,
    /// Per-rank positioned disk reads over the adjacency shards (zero
    /// when the topology is resident).
    pub adj_disk_reads: Vec<u64>,
    /// Per-rank pipeline-prefetcher counters (`None` unless
    /// [`DistOptions::prefetch`] was on).
    pub prefetch: Vec<Option<crate::dist::PrefetchStats>>,
    /// Per-rank content digests ([`batch_digest`]) of every batch the
    /// rank produced, in epoch order — what a real multi-process run
    /// (`pyg2 dist --procs N`) must reproduce seed-for-seed.
    pub digests: Vec<Vec<u64>>,
    pub rank_seconds: Vec<f64>,
    pub batches: usize,
    pub sampled_nodes: usize,
}

impl MountedMultiRankReport {
    /// The row/adjacency/halo cache split of one rank's shared budget.
    pub fn mount_cache_stats(&self, rank: usize) -> crate::persist::MountCacheStats {
        crate::persist::MountCacheStats {
            rows: self.row_cache[rank],
            adj: self.adj_cache[rank],
            halo: self.adj_halo[rank],
        }
    }

    /// Min/max/mean of [`MountedMultiRankReport::rank_seconds`].
    pub fn skew(&self) -> RankSkew {
        RankSkew::from_seconds(&self.rank_seconds)
    }
}

/// Multi-rank simulation over a mounted bundle: one out-of-core
/// [`crate::dist::DistNeighborLoader`] per rank, each mounting the
/// bundle from its own rank's view and training on the seeds its
/// partition owns — the full distributed pipeline with **no rank ever
/// holding the unpartitioned feature matrix in memory** (feature rows
/// are demand-paged; adjacency shards are decoded at mount, or with
/// `lru.page_adjacency` demand-paged too, so O(batch) memory covers
/// features *and* topology). Aggregates every rank's traffic row into
/// a [`crate::dist::TrafficMatrix`] alongside the per-rank cache and
/// disk-I/O ledgers (row and adjacency halves reported separately).
pub fn multi_rank_epoch_mounted(
    bundle: &crate::persist::Bundle,
    ranks: usize,
    cfg: &LoaderConfig,
    opts: DistOptions,
    lru: crate::persist::LruConfig,
    epochs: u64,
) -> Result<MountedMultiRankReport> {
    use crate::error::Error;
    use crate::storage::DEFAULT_GROUP;

    if bundle.is_typed() {
        return Err(Error::Config(
            "multi-rank mounted simulation covers homogeneous bundles only; \
             run typed bundles one rank at a time (hetero_mounted_loader / --rank R)"
                .into(),
        ));
    }
    let parts = bundle.num_parts();
    if ranks == 0 || ranks > parts {
        return Err(Error::Config(format!(
            "{ranks} ranks over {parts} partitions (need 1..=num_parts)"
        )));
    }
    let assignment = bundle.load_assignment(DEFAULT_GROUP)?;
    let mut matrix = crate::dist::TrafficMatrix::new(ranks, parts);
    let mut halo = Vec::with_capacity(ranks);
    let mut row_cache = Vec::with_capacity(ranks);
    let mut adj_cache = Vec::with_capacity(ranks);
    let mut adj_halo = Vec::with_capacity(ranks);
    let mut disk_reads = Vec::with_capacity(ranks);
    let mut adj_disk_reads = Vec::with_capacity(ranks);
    let mut prefetch = Vec::with_capacity(ranks);
    let mut digests = Vec::with_capacity(ranks);
    let mut rank_seconds = Vec::with_capacity(ranks);
    let mut batches = 0usize;
    let mut sampled_nodes = 0usize;
    for rank in 0..ranks as u32 {
        let seeds: Vec<u32> = assignment
            .iter()
            .enumerate()
            .filter(|(_, &a)| a == rank)
            .map(|(v, _)| v as u32)
            .collect();
        let loader = mounted_loader(bundle, rank, seeds, cfg.clone(), opts, lru)?;
        let mut rank_digests = Vec::new();
        let t_rank = Instant::now();
        for epoch in 0..epochs {
            for batch in loader.iter_epoch(epoch) {
                let b = batch?;
                batches += 1;
                sampled_nodes += b.num_real_nodes();
                rank_digests.push(batch_digest(&b));
            }
        }
        let rank_secs = t_rank.elapsed().as_secs_f64();
        record_rank_epoch(rank, rank_secs);
        rank_seconds.push(rank_secs);
        matrix.set_rank(rank as usize, &loader.graph().router().traffic_by_partition())?;
        halo.push(loader.cache_stats());
        // Stat collection must not panic if a future caller wires a
        // resident store through here: the mount ledgers just read as
        // empty.
        row_cache.push(loader.features().row_cache_stats().unwrap_or_default());
        adj_cache.push(loader.graph().adj_cache_stats());
        adj_halo.push(loader.graph().adj_halo_stats());
        disk_reads.push(loader.features().disk_reads().unwrap_or(0));
        adj_disk_reads.push(loader.graph().adj_disk_reads().unwrap_or(0));
        prefetch.push(loader.prefetch_stats());
        digests.push(rank_digests);
    }
    Ok(MountedMultiRankReport {
        matrix,
        halo,
        row_cache,
        adj_cache,
        adj_halo,
        disk_reads,
        adj_disk_reads,
        prefetch,
        digests,
        rank_seconds,
        batches,
        sampled_nodes,
    })
}

/// Result of a [`multi_rank_epoch_hetero`] simulation: the combined
/// `rank × partition` traffic matrix, its per-node-type breakdown, the
/// per-edge-type message counts summed over ranks, per-`(rank, type)`
/// cache counters, and per-rank wall-clock.
#[derive(Debug)]
pub struct HeteroMultiRankReport {
    /// Traffic summed over node types.
    pub matrix: crate::dist::TrafficMatrix,
    /// Per-node-type `rank × partition` matrices (the typed traffic the
    /// tentpole threads through the coordinator).
    pub per_type: std::collections::BTreeMap<String, crate::dist::TrafficMatrix>,
    /// Per-edge-type traffic summed over ranks (adjacency reads,
    /// attributed to the relation that caused them).
    pub edge_traffic: std::collections::BTreeMap<crate::graph::EdgeType, crate::dist::RouterStats>,
    /// Per-rank, per-node-type halo-cache counters (empty maps when
    /// caching was off).
    pub cache: Vec<std::collections::BTreeMap<String, crate::dist::CacheStats>>,
    /// Wall-clock seconds each rank spent on its epochs.
    pub rank_seconds: Vec<f64>,
    pub batches: usize,
    pub sampled_nodes: usize,
}

impl HeteroMultiRankReport {
    /// Min/max/mean of [`HeteroMultiRankReport::rank_seconds`].
    pub fn skew(&self) -> RankSkew {
        RankSkew::from_seconds(&self.rank_seconds)
    }
}

/// Multi-rank simulation of the typed pipeline: one
/// [`crate::dist::HeteroDistNeighborLoader`] per rank over the
/// `seed_type` seeds that rank *owns* (the realistic distributed
/// workload), each viewing the cluster from its rank. Runs `epochs`
/// epochs per rank and aggregates every rank's per-type routers into a
/// combined and a per-type [`crate::dist::TrafficMatrix`].
pub fn multi_rank_epoch_hetero(
    graph: &crate::graph::HeteroGraph,
    partitioning: &crate::partition::TypedPartitioning,
    seed_type: &str,
    ranks: usize,
    cfg: &crate::loader::HeteroLoaderConfig,
    opts: DistOptions,
    epochs: u64,
) -> Result<HeteroMultiRankReport> {
    use crate::error::Error;
    use std::collections::BTreeMap;

    if ranks == 0 || ranks > partitioning.num_parts {
        return Err(Error::Config(format!(
            "{ranks} ranks over {} partitions (need 1..=num_parts)",
            partitioning.num_parts
        )));
    }
    partitioning.partitioning(seed_type)?; // validate the seed type early
    let parts = partitioning.num_parts;
    let mut matrix = crate::dist::TrafficMatrix::new(ranks, parts);
    let mut per_type: BTreeMap<String, crate::dist::TrafficMatrix> = partitioning
        .node_types()
        .map(|nt| (nt.to_string(), crate::dist::TrafficMatrix::new(ranks, parts)))
        .collect();
    let mut edge_traffic: BTreeMap<crate::graph::EdgeType, crate::dist::RouterStats> =
        BTreeMap::new();
    let mut cache = Vec::with_capacity(ranks);
    let mut rank_seconds = Vec::with_capacity(ranks);
    let mut batches = 0usize;
    let mut sampled_nodes = 0usize;
    // One sweep computes every (type, rank) halo.
    let halos = if opts.halo_cache {
        Some(partitioning.halos(graph)?)
    } else {
        None
    };
    for rank in 0..ranks as u32 {
        let seeds = partitioning.nodes_of(seed_type, rank);
        let loader = build_hetero_partitioned_loader(
            graph,
            partitioning,
            rank,
            seed_type,
            seeds,
            cfg.clone(),
            opts,
            halos.as_ref(),
        )?;
        let t_rank = Instant::now();
        for epoch in 0..epochs {
            for batch in loader.iter_epoch(epoch) {
                let b = batch?;
                batches += 1;
                sampled_nodes += b.total_nodes();
            }
        }
        let rank_secs = t_rank.elapsed().as_secs_f64();
        record_rank_epoch(rank, rank_secs);
        rank_seconds.push(rank_secs);
        let router = loader.graph().typed_router();
        matrix.set_rank(rank as usize, &router.traffic_by_partition())?;
        for (nt, traffic) in router.traffic_by_type() {
            per_type
                .get_mut(&nt)
                .expect("type known to the partitioning")
                .set_rank(rank as usize, &traffic)?;
        }
        for (et, stats) in loader.edge_traffic() {
            *edge_traffic.entry(et).or_default() += stats;
        }
        cache.push(loader.cache_stats());
    }
    Ok(HeteroMultiRankReport {
        matrix,
        per_type,
        edge_traffic,
        cache,
        rank_seconds,
        batches,
        sampled_nodes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::sbm::{self, SbmConfig};

    #[test]
    fn multi_rank_matrix_covers_all_ranks_and_cache_cuts_rows() {
        let g = sbm::generate(&SbmConfig { num_nodes: 400, seed: 3, ..Default::default() })
            .unwrap();
        let p = crate::partition::ldg_partition(&g.edge_index, 4, 1.1).unwrap();
        let cfg = LoaderConfig {
            batch_size: 32,
            num_workers: 1,
            shuffle: false,
            sampler: crate::sampler::NeighborSamplerConfig {
                fanouts: vec![4, 2],
                ..Default::default()
            },
            ..Default::default()
        };
        let base =
            multi_rank_epoch(&g, &p, 4, &cfg, DistOptions::default(), 1).unwrap();
        assert_eq!(base.matrix.num_ranks(), 4);
        assert_eq!(base.matrix.num_parts(), 4);
        assert!(base.batches >= 4, "every rank ran at least one batch");
        assert!(base.sampled_nodes > 0);
        for r in 0..4 {
            assert!(base.matrix.msgs(r, r) > 0, "rank {r} made local accesses");
        }
        assert!(base.matrix.total_remote_msgs() > 0, "4-way epoch crosses partitions");
        assert!(base.cache.iter().all(|c| c.is_none()), "caching was off");
        assert_eq!(base.shard_edges.len(), 4);
        let stored: usize = base.shard_edges.iter().map(|&(i, _)| i).sum();
        assert_eq!(stored, g.num_edges(), "in-shards tile the edge set");

        // Same workload with halo caching + async routing: strictly fewer
        // payload rows cross partitions (halo hits ship nothing), and the
        // per-rank caches report the hits.
        let cached = multi_rank_epoch(
            &g,
            &p,
            4,
            &cfg,
            DistOptions { halo_cache: true, async_fetch: true, ..Default::default() },
            1,
        )
        .unwrap();
        assert!(
            cached.matrix.total_remote_rows() < base.matrix.total_remote_rows(),
            "halo cache must cut cross-partition rows: {} vs {}",
            cached.matrix.total_remote_rows(),
            base.matrix.total_remote_rows()
        );
        for (r, stats) in cached.cache.iter().enumerate() {
            let stats = stats.expect("cache stats present");
            assert!(stats.hits > 0, "rank {r} served halo rows locally");
        }
    }

    #[test]
    fn multi_rank_reports_per_rank_wall_clock_skew() {
        let g = sbm::generate(&SbmConfig { num_nodes: 200, seed: 2, ..Default::default() })
            .unwrap();
        let p = crate::partition::ldg_partition(&g.edge_index, 2, 1.1).unwrap();
        let cfg = LoaderConfig { batch_size: 32, num_workers: 1, ..Default::default() };
        let report = multi_rank_epoch(&g, &p, 2, &cfg, DistOptions::default(), 1).unwrap();
        assert_eq!(report.rank_seconds.len(), 2);
        assert!(report.rank_seconds.iter().all(|&s| s >= 0.0));
        let skew = report.skew();
        assert!(skew.min <= skew.mean && skew.mean <= skew.max);
        assert!(skew.imbalance() >= 1.0);
        let shown = skew.to_string();
        assert!(shown.contains("max/min"), "{shown}");
        assert!(RankSkew::from_seconds(&[]).imbalance() >= 1.0);
    }

    #[test]
    fn hetero_multi_rank_aggregates_typed_traffic() {
        let g = crate::datasets::hetero::generate(&crate::datasets::HeteroSbmConfig {
            num_users: 200,
            num_items: 120,
            num_tags: 40,
            seed: 5,
            ..Default::default()
        })
        .unwrap();
        let tp = crate::partition::TypedPartitioning::ldg_hetero(&g, 4, 1.1).unwrap();
        let cfg = crate::loader::HeteroLoaderConfig {
            batch_size: 32,
            num_workers: 1,
            shuffle: false,
            sampler: crate::sampler::HeteroSamplerConfig {
                default_fanouts: vec![3, 2],
                ..Default::default()
            },
            ..Default::default()
        };
        let base =
            multi_rank_epoch_hetero(&g, &tp, "user", 4, &cfg, DistOptions::default(), 1).unwrap();
        assert_eq!(base.matrix.num_ranks(), 4);
        assert!(base.batches >= 4);
        assert!(base.sampled_nodes > 0);
        assert_eq!(base.rank_seconds.len(), 4);
        assert!(base.matrix.total_remote_msgs() > 0, "typed epoch crosses partitions");
        // Per-type matrices tile the combined one.
        assert_eq!(base.per_type.len(), 3);
        for r in 0..4 {
            for p in 0..4 {
                let sum: u64 = base.per_type.values().map(|m| m.msgs(r, p)).sum();
                assert_eq!(sum, base.matrix.msgs(r, p), "cell ({r}, {p})");
            }
        }
        // Per-edge-type attribution covers every relation.
        assert_eq!(base.edge_traffic.len(), 4);
        assert!(base.cache.iter().all(|c| c.is_empty()), "caching was off");

        // Caching strictly cuts cross-partition payload, per type.
        let cached = multi_rank_epoch_hetero(
            &g,
            &tp,
            "user",
            4,
            &cfg,
            DistOptions { halo_cache: true, async_fetch: true, ..Default::default() },
            1,
        )
        .unwrap();
        assert!(
            cached.matrix.total_remote_rows() < base.matrix.total_remote_rows(),
            "typed halo caches must cut cross-partition rows: {} vs {}",
            cached.matrix.total_remote_rows(),
            base.matrix.total_remote_rows()
        );
        for (rank, stats) in cached.cache.iter().enumerate() {
            assert!(!stats.is_empty(), "rank {rank} has per-type caches");
            assert!(
                stats.values().any(|s| s.hits > 0),
                "rank {rank} served halo rows locally"
            );
        }
        // Bad rank counts / seed types rejected.
        assert!(multi_rank_epoch_hetero(&g, &tp, "user", 0, &cfg, DistOptions::default(), 1)
            .is_err());
        assert!(multi_rank_epoch_hetero(&g, &tp, "user", 5, &cfg, DistOptions::default(), 1)
            .is_err());
        assert!(multi_rank_epoch_hetero(&g, &tp, "ghost", 2, &cfg, DistOptions::default(), 1)
            .is_err());
    }

    #[test]
    fn multi_rank_rejects_bad_rank_counts() {
        let g = sbm::generate(&SbmConfig { num_nodes: 100, seed: 1, ..Default::default() })
            .unwrap();
        let p = crate::partition::ldg_partition(&g.edge_index, 2, 1.1).unwrap();
        let cfg = LoaderConfig { batch_size: 16, num_workers: 1, ..Default::default() };
        assert!(multi_rank_epoch(&g, &p, 0, &cfg, DistOptions::default(), 1).is_err());
        assert!(multi_rank_epoch(&g, &p, 3, &cfg, DistOptions::default(), 1).is_err());
    }

    fn engine() -> Option<Engine> {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            Some(Engine::load("artifacts").unwrap())
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn compiled_training_learns_sbm() {
        let Some(engine) = engine() else { return };
        let b = &engine.manifest().bucket;
        let g = sbm::generate(&SbmConfig {
            num_nodes: 600,
            num_blocks: b.c,
            feature_dim: b.f,
            feature_signal: 1.5,
            seed: 5,
            ..Default::default()
        })
        .unwrap();
        let loader = default_loader(&engine, &g, (0..256).collect(), 1);
        let trainer = Trainer::new(
            &engine,
            TrainConfig { epochs: 15, log_every: 0, ..Default::default() },
        );
        let report = trainer.train(&loader).unwrap();
        assert!(report.history.len() >= 60);
        let first_acc = report.history[0].accuracy;
        let final_acc = report.recent_accuracy(4);
        assert!(
            final_acc > 0.5 && final_acc > first_acc,
            "acc {first_acc} -> {final_acc}"
        );
        assert!(report.final_loss() < report.history[0].loss);
    }

    #[test]
    fn eager_and_compiled_agree_on_first_step() {
        let Some(engine) = engine() else { return };
        let b = &engine.manifest().bucket;
        let g = sbm::generate(&SbmConfig {
            num_nodes: 400,
            num_blocks: b.c,
            feature_dim: b.f,
            seed: 9,
            ..Default::default()
        })
        .unwrap();
        let loader = default_loader(&engine, &g, (0..b.s as u32).collect(), 1);
        let mk = |mode| {
            Trainer::new(
                &engine,
                TrainConfig { mode, epochs: 1, log_every: 0, ..Default::default() },
            )
            .train(&loader)
            .unwrap()
        };
        let compiled = mk(RunMode::Compiled);
        let eager = mk(RunMode::Eager);
        // Same params/batches -> same first-step loss across modes.
        assert!(
            (compiled.history[0].loss - eager.history[0].loss).abs() < 1e-4,
            "compiled {} vs eager {}",
            compiled.history[0].loss,
            eager.history[0].loss
        );
    }
}
