//! Training coordinator: drives the loader → runtime pipeline for both
//! execution modes, tracks metrics, and owns the parameter update cycle.
//! This is the Rust-side "training loop that looks identical regardless
//! of backend" promised by the FeatureStore/GraphStore split (§2.3).

pub mod serve;

pub use serve::{InferenceServer, Prediction, ServeConfig, ServeStats};

use crate::error::Result;
use crate::loader::{Batch, LoaderConfig, NeighborLoader};
use crate::nn::ParamStore;
use crate::runtime::{EagerExecutor, Engine, Value};
use crate::storage::{FeatureStore, GraphStore};
use crate::tensor::argmax_rows;
use std::collections::HashMap;
use std::time::Instant;

/// Execution mode for the neural layer (the Tables 1-2 axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunMode {
    /// Op-by-op micro-op dispatch (PyTorch-eager analog).
    Eager,
    /// Single fused HLO (torch.compile analog).
    Compiled,
}

/// Training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub arch: String,
    pub mode: RunMode,
    pub trim: bool,
    pub epochs: usize,
    pub param_seed: u64,
    /// Log every n steps (0 = silent).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            arch: "gcn".into(),
            mode: RunMode::Compiled,
            trim: false,
            epochs: 3,
            param_seed: 7,
            log_every: 10,
        }
    }
}

/// Per-step record of the training history.
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub epoch: usize,
    pub step: usize,
    pub loss: f32,
    pub accuracy: f32,
    pub millis: f64,
}

/// Result of a training run.
#[derive(Debug)]
pub struct TrainReport {
    pub history: Vec<StepRecord>,
    pub final_params: ParamStore,
    pub mode: RunMode,
    pub total_seconds: f64,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        self.history.last().map(|r| r.loss).unwrap_or(f32::NAN)
    }

    /// Mean accuracy over the last `n` steps.
    pub fn recent_accuracy(&self, n: usize) -> f32 {
        let tail = &self.history[self.history.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().map(|r| r.accuracy).sum::<f32>() / tail.len() as f32
    }

    pub fn mean_step_ms(&self) -> f64 {
        if self.history.is_empty() {
            return f64::NAN;
        }
        self.history.iter().map(|r| r.millis).sum::<f64>() / self.history.len() as f64
    }
}

/// Program name for (arch, mode, trim) per the manifest naming scheme.
pub fn program_name(arch: &str, mode: RunMode, trim: bool) -> String {
    let base = match mode {
        RunMode::Eager => format!("{arch}_eager"),
        RunMode::Compiled => format!("{arch}_train"),
    };
    if trim {
        format!("{base}_trim")
    } else {
        base
    }
}

/// The trainer.
pub struct Trainer<'e> {
    engine: &'e Engine,
    cfg: TrainConfig,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: TrainConfig) -> Self {
        Self { engine, cfg }
    }

    /// Train over a loader; returns per-step history and final params.
    pub fn train<G, F>(&self, loader: &NeighborLoader<G, F>) -> Result<TrainReport>
    where
        G: GraphStore + 'static,
        F: FeatureStore + 'static,
    {
        let program = program_name(&self.cfg.arch, self.cfg.mode, self.cfg.trim);
        let mut store = ParamStore::init_for(self.engine.manifest(), &program, self.cfg.param_seed)?;
        let mut history = Vec::new();
        let t0 = Instant::now();

        match self.cfg.mode {
            RunMode::Compiled => {
                // Warm the executable cache outside the timed region.
                if let crate::runtime::Program::Fused { file, .. } =
                    self.engine.manifest().program(&program)?
                {
                    let file = file.clone();
                    self.engine.executable(&file)?;
                }
                let mut step_idx = 0;
                for epoch in 0..self.cfg.epochs {
                    for batch in loader.iter_epoch(epoch as u64) {
                        let batch = batch?;
                        let t = Instant::now();
                        let inputs = Engine::batch_inputs(&batch);
                        let out = self.engine.run_fused(&program, store.values_ref(), &inputs)?;
                        let millis = t.elapsed().as_secs_f64() * 1e3;
                        let loss = out[0].scalar_f32()?;
                        let accuracy = seed_accuracy(&out[1], &batch)?;
                        store.update_from_fused_output(&out)?;
                        self.log(epoch, step_idx, loss, accuracy);
                        history.push(StepRecord { epoch, step: step_idx, loss, accuracy, millis });
                        step_idx += 1;
                    }
                }
            }
            RunMode::Eager => {
                let exec = EagerExecutor::new(self.engine, &program)?;
                exec.warmup()?;
                let mut params: HashMap<String, Value> = store.as_map();
                let mut step_idx = 0;
                for epoch in 0..self.cfg.epochs {
                    for batch in loader.iter_epoch(epoch as u64) {
                        let batch = batch?;
                        let t = Instant::now();
                        let inputs = Engine::batch_inputs(&batch);
                        let (loss, logits) = exec.train_step(&mut params, &inputs)?;
                        let millis = t.elapsed().as_secs_f64() * 1e3;
                        let accuracy = seed_accuracy(&logits, &batch)?;
                        self.log(epoch, step_idx, loss, accuracy);
                        history.push(StepRecord { epoch, step: step_idx, loss, accuracy, millis });
                        step_idx += 1;
                    }
                }
                store.update_from_map(&params)?;
            }
        }

        Ok(TrainReport {
            history,
            final_params: store,
            mode: self.cfg.mode,
            total_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    fn log(&self, epoch: usize, step: usize, loss: f32, acc: f32) {
        if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
            log::info!(
                "epoch {epoch} step {step}: loss={loss:.4} acc={acc:.3} ({:?} {})",
                self.cfg.mode,
                self.cfg.arch
            );
        }
    }
}

/// Seed-level accuracy from a logits value `[S, C]`.
pub fn seed_accuracy(logits: &Value, batch: &Batch) -> Result<f32> {
    let t = logits.to_tensor()?;
    let preds = argmax_rows(&t);
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..batch.num_real_seeds() {
        if batch.labels[i] >= 0 {
            total += 1;
            if preds[i] as i32 == batch.labels[i] {
                correct += 1;
            }
        }
    }
    Ok(if total == 0 { 0.0 } else { correct as f32 / total as f32 })
}

/// Convenience: a loader matching the manifest's default bucket over an
/// SBM graph (the quickstart / bench workload).
pub fn default_loader(
    engine: &Engine,
    graph: &crate::graph::Graph,
    seeds: Vec<u32>,
    num_workers: usize,
) -> NeighborLoader<crate::storage::InMemoryGraphStore, crate::storage::InMemoryFeatureStore> {
    let bucket = engine.manifest().bucket.clone();
    let gs = std::sync::Arc::new(crate::storage::InMemoryGraphStore::from_graph(graph));
    let fs = std::sync::Arc::new(crate::storage::InMemoryFeatureStore::from_tensor(graph.x.clone()));
    let mut loader = NeighborLoader::new(
        gs,
        fs,
        seeds,
        LoaderConfig {
            batch_size: bucket.s,
            num_workers,
            shuffle: true,
            sampler: crate::sampler::NeighborSamplerConfig {
                fanouts: bucket.fanouts.clone(),
                ..Default::default()
            },
            bucket: Some(bucket.to_shape_bucket()),
            ..Default::default()
        },
    );
    if let Some(y) = &graph.y {
        loader = loader.with_labels(y.clone());
    }
    loader
}

/// The partitioned serving path (§2.3): wire a graph through the full
/// distributed stack — one shared [`crate::dist::PartitionRouter`],
/// partitioned feature + graph stores, and a
/// [`crate::dist::DistNeighborLoader`] — viewed from `local_rank`.
///
/// With the same [`LoaderConfig`] this yields batches identical to the
/// single-store loader; the returned loader's `router_stats()` report the
/// cross-partition traffic the partitioning saved or cost.
pub fn partitioned_loader(
    graph: &crate::graph::Graph,
    partitioning: &crate::partition::Partitioning,
    local_rank: u32,
    seeds: Vec<u32>,
    cfg: LoaderConfig,
) -> Result<crate::dist::DistNeighborLoader> {
    use crate::dist::{DistNeighborLoader, PartitionRouter, PartitionedFeatureStore, PartitionedGraphStore};
    use std::sync::Arc;

    let router = Arc::new(PartitionRouter::new(partitioning, local_rank)?);
    let gs = Arc::new(PartitionedGraphStore::from_graph(graph, Arc::clone(&router))?);
    let src_features = crate::storage::InMemoryFeatureStore::from_tensor(graph.x.clone());
    let fs = Arc::new(PartitionedFeatureStore::partition(&src_features, router)?);
    let mut loader = DistNeighborLoader::new(gs, fs, seeds, cfg);
    if let Some(y) = &graph.y {
        loader = loader.with_labels(y.clone());
    }
    Ok(loader)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::sbm::{self, SbmConfig};

    fn engine() -> Option<Engine> {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            Some(Engine::load("artifacts").unwrap())
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn compiled_training_learns_sbm() {
        let Some(engine) = engine() else { return };
        let b = &engine.manifest().bucket;
        let g = sbm::generate(&SbmConfig {
            num_nodes: 600,
            num_blocks: b.c,
            feature_dim: b.f,
            feature_signal: 1.5,
            seed: 5,
            ..Default::default()
        })
        .unwrap();
        let loader = default_loader(&engine, &g, (0..256).collect(), 1);
        let trainer = Trainer::new(
            &engine,
            TrainConfig { epochs: 15, log_every: 0, ..Default::default() },
        );
        let report = trainer.train(&loader).unwrap();
        assert!(report.history.len() >= 60);
        let first_acc = report.history[0].accuracy;
        let final_acc = report.recent_accuracy(4);
        assert!(
            final_acc > 0.5 && final_acc > first_acc,
            "acc {first_acc} -> {final_acc}"
        );
        assert!(report.final_loss() < report.history[0].loss);
    }

    #[test]
    fn eager_and_compiled_agree_on_first_step() {
        let Some(engine) = engine() else { return };
        let b = &engine.manifest().bucket;
        let g = sbm::generate(&SbmConfig {
            num_nodes: 400,
            num_blocks: b.c,
            feature_dim: b.f,
            seed: 9,
            ..Default::default()
        })
        .unwrap();
        let loader = default_loader(&engine, &g, (0..b.s as u32).collect(), 1);
        let mk = |mode| {
            Trainer::new(
                &engine,
                TrainConfig { mode, epochs: 1, log_every: 0, ..Default::default() },
            )
            .train(&loader)
            .unwrap()
        };
        let compiled = mk(RunMode::Compiled);
        let eager = mk(RunMode::Eager);
        // Same params/batches -> same first-step loss across modes.
        assert!(
            (compiled.history[0].loss - eager.history[0].loss).abs() < 1e-4,
            "compiled {} vs eager {}",
            compiled.history[0].loss,
            eager.history[0].loss
        );
    }
}
