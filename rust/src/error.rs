//! Unified error type for the framework.

/// Framework-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Framework-wide error enum.
#[derive(thiserror::Error, Debug)]
pub enum Error {
    #[error("shape error: {0}")]
    Shape(String),

    #[error("graph error: {0}")]
    Graph(String),

    #[error("storage error: {0}")]
    Storage(String),

    #[error("sampler error: {0}")]
    Sampler(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
