//! Unified error type for the framework.
//!
//! Hand-rolled `Display`/`Error` impls (the `thiserror` derive macro is
//! unavailable in the offline sandbox).

use std::fmt;

/// Framework-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Framework-wide error enum.
#[derive(Debug)]
pub enum Error {
    Shape(String),
    Graph(String),
    Storage(String),
    Sampler(String),
    Runtime(String),
    Config(String),
    Io(std::io::Error),
    Xla(String),
    /// A serving request missed its latency budget and was rejected
    /// rather than queued unboundedly.
    Deadline(String),
    /// A mounted-store operation was attempted on a store that is not
    /// mounted (or whose mount state is unavailable).
    Mount(String),
    /// A distributed worker process failed, died, or missed a deadline.
    Worker(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Graph(m) => write!(f, "graph error: {m}"),
            Error::Storage(m) => write!(f, "storage error: {m}"),
            Error::Sampler(m) => write!(f, "sampler error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Deadline(m) => write!(f, "deadline exceeded: {m}"),
            Error::Mount(m) => write!(f, "mount error: {m}"),
            Error::Worker(m) => write!(f, "worker failure: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
