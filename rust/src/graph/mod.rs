//! Graph data structures: `EdgeIndex` (COO with cached CSR/CSC — §2.2),
//! homogeneous `Graph`, and `HeteroGraph` with typed node/edge stores.

pub mod edge_index;
pub mod hetero;
pub mod homogeneous;

pub use edge_index::{Compressed, EdgeIndex, SortOrder};
pub use hetero::{EdgeStore, EdgeType, HeteroGraph, NodeStore};
pub use homogeneous::Graph;
