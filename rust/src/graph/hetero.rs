//! Heterogeneous graph container (§2.2 "Heterogeneous Message Passing").
//!
//! A heterogeneous graph G = (V, E, φ, ψ) assigns every node a node type in
//! 𝒯 and every edge a relation triple (src_type, rel, dst_type) in ℛ.
//! Mirrors PyG's `HeteroData`: per-node-type feature/label stores and
//! per-edge-type [`EdgeIndex`]es over *local* (per-type) node ids.

use super::edge_index::EdgeIndex;
use crate::error::{Error, Result};
use crate::tensor::Tensor;
use std::collections::BTreeMap;

/// A relation triple `(src_type, relation, dst_type)`.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct EdgeType {
    pub src: String,
    pub rel: String,
    pub dst: String,
}

impl EdgeType {
    pub fn new(src: &str, rel: &str, dst: &str) -> Self {
        Self { src: src.into(), rel: rel.into(), dst: dst.into() }
    }

    /// Canonical string form `src__rel__dst` (artifact naming, logs).
    pub fn key(&self) -> String {
        format!("{}__{}__{}", self.src, self.rel, self.dst)
    }
}

/// Per-node-type storage.
#[derive(Clone, Debug)]
pub struct NodeStore {
    pub x: Tensor,
    pub y: Option<Vec<i64>>,
    /// Per-node timestamps; `None` for static types (paper: "for node and
    /// edge types lacking timestamps sampling is performed without applying
    /// temporal constraints").
    pub time: Option<Vec<i64>>,
}

/// Per-edge-type storage.
#[derive(Clone, Debug)]
pub struct EdgeStore {
    pub edge_index: EdgeIndex,
    pub time: Option<Vec<i64>>,
}

/// Heterogeneous attributed graph.
#[derive(Clone, Debug, Default)]
pub struct HeteroGraph {
    nodes: BTreeMap<String, NodeStore>,
    edges: BTreeMap<EdgeType, EdgeStore>,
}

impl HeteroGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a node type with features.
    pub fn add_node_type(&mut self, name: &str, x: Tensor) -> Result<()> {
        if self.nodes.contains_key(name) {
            return Err(Error::Graph(format!("duplicate node type {name}")));
        }
        self.nodes.insert(name.to_string(), NodeStore { x, y: None, time: None });
        Ok(())
    }

    pub fn set_labels(&mut self, node_type: &str, y: Vec<i64>) -> Result<()> {
        let store = self.node_store_mut(node_type)?;
        if y.len() != store.x.rows() {
            return Err(Error::Graph(format!(
                "label count {} != node count {}",
                y.len(),
                store.x.rows()
            )));
        }
        store.y = Some(y);
        Ok(())
    }

    pub fn set_node_time(&mut self, node_type: &str, t: Vec<i64>) -> Result<()> {
        let store = self.node_store_mut(node_type)?;
        if t.len() != store.x.rows() {
            return Err(Error::Graph(format!(
                "time count {} != node count {}",
                t.len(),
                store.x.rows()
            )));
        }
        store.time = Some(t);
        Ok(())
    }

    /// Register an edge type. Endpoint node types must already exist and
    /// the edge index must be consistent with their sizes.
    pub fn add_edge_type(&mut self, et: EdgeType, edge_index: EdgeIndex) -> Result<()> {
        let n_src = self.num_nodes(&et.src)?;
        let n_dst = self.num_nodes(&et.dst)?;
        // EdgeIndex is validated against a single node count; for bipartite
        // edge types we validate endpoints explicitly.
        for &s in edge_index.src() {
            if s as usize >= n_src {
                return Err(Error::Graph(format!("src {s} out of range for {}", et.src)));
            }
        }
        for &d in edge_index.dst() {
            if d as usize >= n_dst {
                return Err(Error::Graph(format!("dst {d} out of range for {}", et.dst)));
            }
        }
        if self.edges.contains_key(&et) {
            return Err(Error::Graph(format!("duplicate edge type {}", et.key())));
        }
        self.edges.insert(et, EdgeStore { edge_index, time: None });
        Ok(())
    }

    pub fn set_edge_time(&mut self, et: &EdgeType, t: Vec<i64>) -> Result<()> {
        let store = self
            .edges
            .get_mut(et)
            .ok_or_else(|| Error::Graph(format!("unknown edge type {}", et.key())))?;
        if t.len() != store.edge_index.num_edges() {
            return Err(Error::Graph(format!(
                "edge time count {} != edge count {}",
                t.len(),
                store.edge_index.num_edges()
            )));
        }
        store.time = Some(t);
        Ok(())
    }

    pub fn node_types(&self) -> impl Iterator<Item = &str> {
        self.nodes.keys().map(|s| s.as_str())
    }

    pub fn edge_types(&self) -> impl Iterator<Item = &EdgeType> {
        self.edges.keys()
    }

    pub fn num_node_types(&self) -> usize {
        self.nodes.len()
    }

    pub fn num_edge_types(&self) -> usize {
        self.edges.len()
    }

    pub fn node_store(&self, node_type: &str) -> Result<&NodeStore> {
        self.nodes
            .get(node_type)
            .ok_or_else(|| Error::Graph(format!("unknown node type {node_type}")))
    }

    fn node_store_mut(&mut self, node_type: &str) -> Result<&mut NodeStore> {
        self.nodes
            .get_mut(node_type)
            .ok_or_else(|| Error::Graph(format!("unknown node type {node_type}")))
    }

    pub fn edge_store(&self, et: &EdgeType) -> Result<&EdgeStore> {
        self.edges
            .get(et)
            .ok_or_else(|| Error::Graph(format!("unknown edge type {}", et.key())))
    }

    pub fn num_nodes(&self, node_type: &str) -> Result<usize> {
        Ok(self.node_store(node_type)?.x.rows())
    }

    pub fn total_nodes(&self) -> usize {
        self.nodes.values().map(|s| s.x.rows()).sum()
    }

    pub fn total_edges(&self) -> usize {
        self.edges.values().map(|s| s.edge_index.num_edges()).sum()
    }

    /// Edge types whose destination is `node_type` (the "incoming relations"
    /// the nested hetero aggregation in Eq. (1) runs over).
    pub fn incoming_edge_types(&self, node_type: &str) -> Vec<&EdgeType> {
        self.edges.keys().filter(|et| et.dst == node_type).collect()
    }

    /// Flatten into a homogeneous graph with global contiguous node ids
    /// (offset per type, in BTreeMap order). Returns the graph-wide
    /// `EdgeIndex`, per-type offsets, and total node count. Used by
    /// partitioning and full-graph analytics.
    pub fn to_homogeneous_topology(&self) -> (EdgeIndex, BTreeMap<String, usize>, usize) {
        let mut offsets = BTreeMap::new();
        let mut total = 0usize;
        for (name, store) in &self.nodes {
            offsets.insert(name.clone(), total);
            total += store.x.rows();
        }
        let mut src = Vec::with_capacity(self.total_edges());
        let mut dst = Vec::with_capacity(self.total_edges());
        for (et, store) in &self.edges {
            let so = offsets[&et.src] as u32;
            let do_ = offsets[&et.dst] as u32;
            for (&s, &d) in store.edge_index.src().iter().zip(store.edge_index.dst()) {
                src.push(so + s);
                dst.push(do_ + d);
            }
        }
        let ei = EdgeIndex::new(src, dst, total).expect("valid by construction");
        (ei, offsets, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> HeteroGraph {
        let mut g = HeteroGraph::new();
        g.add_node_type("user", Tensor::zeros(vec![3, 4])).unwrap();
        g.add_node_type("item", Tensor::zeros(vec![2, 4])).unwrap();
        let ei = EdgeIndex::new(vec![0, 1, 2], vec![0, 1, 0], 3).unwrap();
        g.add_edge_type(EdgeType::new("user", "buys", "item"), ei).unwrap();
        g
    }

    #[test]
    fn bipartite_range_validation() {
        let mut g = toy();
        // dst 5 out of range for "item" (2 nodes)
        let bad = EdgeIndex::new(vec![0], vec![5], 6).unwrap();
        assert!(g.add_edge_type(EdgeType::new("user", "views", "item"), bad).is_err());
        // unknown node type
        let ei = EdgeIndex::new(vec![0], vec![0], 1).unwrap();
        assert!(g.add_edge_type(EdgeType::new("user", "x", "nope"), ei).is_err());
    }

    #[test]
    fn counts() {
        let g = toy();
        assert_eq!(g.num_node_types(), 2);
        assert_eq!(g.num_edge_types(), 1);
        assert_eq!(g.total_nodes(), 5);
        assert_eq!(g.total_edges(), 3);
        assert_eq!(g.num_nodes("user").unwrap(), 3);
    }

    #[test]
    fn incoming_edge_types() {
        let g = toy();
        let inc = g.incoming_edge_types("item");
        assert_eq!(inc.len(), 1);
        assert_eq!(inc[0].rel, "buys");
        assert!(g.incoming_edge_types("user").is_empty());
    }

    #[test]
    fn to_homogeneous_offsets() {
        let g = toy();
        let (ei, offsets, total) = g.to_homogeneous_topology();
        assert_eq!(total, 5);
        // BTreeMap order: "item" < "user"
        assert_eq!(offsets["item"], 0);
        assert_eq!(offsets["user"], 2);
        // user 0 -> item 0 becomes 2 -> 0
        assert_eq!(ei.src()[0], 2);
        assert_eq!(ei.dst()[0], 0);
    }

    #[test]
    fn duplicate_node_type_rejected() {
        let mut g = toy();
        assert!(g.add_node_type("user", Tensor::zeros(vec![1, 4])).is_err());
    }

    #[test]
    fn labels_and_time_validation() {
        let mut g = toy();
        assert!(g.set_labels("user", vec![0, 1, 0]).is_ok());
        assert!(g.set_labels("user", vec![0]).is_err());
        assert!(g.set_node_time("item", vec![1, 2]).is_ok());
        let et = EdgeType::new("user", "buys", "item");
        assert!(g.set_edge_time(&et, vec![1, 2, 3]).is_ok());
        assert!(g.set_edge_time(&et, vec![1]).is_err());
    }
}
