//! Homogeneous graph container: topology + node/edge features + labels +
//! optional edge timestamps.

use super::edge_index::EdgeIndex;
use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// A homogeneous (single node/edge type) attributed graph.
///
/// Mirrors PyG's `Data`: topology in an [`EdgeIndex`], dense node features
/// `x`, optional labels `y`, optional per-edge timestamps `edge_time`.
#[derive(Clone, Debug)]
pub struct Graph {
    pub edge_index: EdgeIndex,
    /// `[num_nodes, F]` node features.
    pub x: Tensor,
    /// Per-node integer labels (classification), if present.
    pub y: Option<Vec<i64>>,
    /// Per-edge event timestamps (temporal graphs), if present.
    pub edge_time: Option<Vec<i64>>,
    /// Per-node timestamps (first appearance), if present.
    pub node_time: Option<Vec<i64>>,
}

impl Graph {
    pub fn new(edge_index: EdgeIndex, x: Tensor) -> Result<Self> {
        if x.rows() != edge_index.num_nodes() {
            return Err(Error::Graph(format!(
                "feature rows {} != num_nodes {}",
                x.rows(),
                edge_index.num_nodes()
            )));
        }
        Ok(Self { edge_index, x, y: None, edge_time: None, node_time: None })
    }

    pub fn with_labels(mut self, y: Vec<i64>) -> Result<Self> {
        if y.len() != self.num_nodes() {
            return Err(Error::Graph(format!(
                "label count {} != num_nodes {}",
                y.len(),
                self.num_nodes()
            )));
        }
        self.y = Some(y);
        Ok(self)
    }

    pub fn with_edge_time(mut self, t: Vec<i64>) -> Result<Self> {
        if t.len() != self.num_edges() {
            return Err(Error::Graph(format!(
                "edge_time count {} != num_edges {}",
                t.len(),
                self.num_edges()
            )));
        }
        self.edge_time = Some(t);
        Ok(self)
    }

    pub fn with_node_time(mut self, t: Vec<i64>) -> Result<Self> {
        if t.len() != self.num_nodes() {
            return Err(Error::Graph(format!(
                "node_time count {} != num_nodes {}",
                t.len(),
                self.num_nodes()
            )));
        }
        self.node_time = Some(t);
        Ok(self)
    }

    pub fn num_nodes(&self) -> usize {
        self.edge_index.num_nodes()
    }

    pub fn num_edges(&self) -> usize {
        self.edge_index.num_edges()
    }

    pub fn feature_dim(&self) -> usize {
        self.x.cols()
    }

    pub fn num_classes(&self) -> usize {
        self.y
            .as_ref()
            .map(|y| y.iter().copied().max().unwrap_or(-1) as usize + 1)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Graph {
        let ei = EdgeIndex::new(vec![0, 1], vec![1, 2], 3).unwrap();
        Graph::new(ei, Tensor::zeros(vec![3, 4])).unwrap()
    }

    #[test]
    fn validates_feature_rows() {
        let ei = EdgeIndex::new(vec![0], vec![1], 3).unwrap();
        assert!(Graph::new(ei, Tensor::zeros(vec![2, 4])).is_err());
    }

    #[test]
    fn labels_and_classes() {
        let g = toy().with_labels(vec![0, 2, 1]).unwrap();
        assert_eq!(g.num_classes(), 3);
        assert!(toy().with_labels(vec![0]).is_err());
    }

    #[test]
    fn temporal_attrs_validated() {
        assert!(toy().with_edge_time(vec![1, 2]).is_ok());
        assert!(toy().with_edge_time(vec![1]).is_err());
        assert!(toy().with_node_time(vec![1, 2, 3]).is_ok());
        assert!(toy().with_node_time(vec![1]).is_err());
    }
}
