//! `EdgeIndex`: COO edge tensor with sort-order metadata and lazily cached
//! CSR/CSC conversions — the Rust port of PyG 2.0's `EdgeIndex` subclass
//! (§2.2 "Accelerated Message Passing").
//!
//! The paper's observations carried over here:
//! * if edges are sorted by row (source) or column (destination), message
//!   passing can use segmented aggregation instead of atomic scatter;
//! * repeated layer execution re-derives A and Aᵀ every step unless CSR
//!   *and* CSC are cached across calls;
//! * undirected graphs need only one of the two (A = Aᵀ).

use crate::error::{Error, Result};
use std::sync::OnceLock;

/// Declared sort order of the COO pairs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SortOrder {
    /// No known ordering.
    None,
    /// Sorted by source ("row") — CSR derivable by a single scan.
    ByRow,
    /// Sorted by destination ("col") — CSC derivable by a single scan.
    ByCol,
}

/// Compressed sparse representation (CSR when built over rows, CSC when
/// built over cols): `indptr.len() == num_nodes + 1`, `indices` are the
/// opposing endpoints, `perm[i]` maps compressed position `i` back to the
/// original COO edge id (needed to permute edge features consistently).
#[derive(Clone, Debug, PartialEq)]
pub struct Compressed {
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub perm: Vec<u32>,
}

impl Compressed {
    /// Neighbors of node `v` in this compressed layout.
    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.indices[self.indptr[v]..self.indptr[v + 1]]
    }

    /// Original COO edge ids for node `v`'s incident edges.
    pub fn edge_ids(&self, v: usize) -> &[u32] {
        &self.perm[self.indptr[v]..self.indptr[v + 1]]
    }

    pub fn degree(&self, v: usize) -> usize {
        self.indptr[v + 1] - self.indptr[v]
    }

    pub fn num_nodes(&self) -> usize {
        self.indptr.len() - 1
    }

    pub fn num_edges(&self) -> usize {
        self.indices.len()
    }
}

/// COO edge index `[2, E]` over `num_nodes` nodes with cached conversions.
///
/// Caches are filled on demand (`csr()` / `csc()`) and survive for the
/// lifetime of the value; any mutation goes through rebuilding (edge
/// indices are immutable once constructed, like PyG's tensors).
#[derive(Debug)]
pub struct EdgeIndex {
    src: Vec<u32>,
    dst: Vec<u32>,
    num_nodes: usize,
    sort_order: SortOrder,
    is_undirected: bool,
    csr_cache: OnceLock<Compressed>,
    csc_cache: OnceLock<Compressed>,
}

impl Clone for EdgeIndex {
    fn clone(&self) -> Self {
        // Clones share no cache state; caches refill on demand.
        Self {
            src: self.src.clone(),
            dst: self.dst.clone(),
            num_nodes: self.num_nodes,
            sort_order: self.sort_order,
            is_undirected: self.is_undirected,
            csr_cache: OnceLock::new(),
            csc_cache: OnceLock::new(),
        }
    }
}

impl EdgeIndex {
    /// Build from COO pairs, validating ranges and detecting sort order.
    pub fn new(src: Vec<u32>, dst: Vec<u32>, num_nodes: usize) -> Result<Self> {
        if src.len() != dst.len() {
            return Err(Error::Graph(format!(
                "src/dst length mismatch: {} vs {}",
                src.len(),
                dst.len()
            )));
        }
        for (&s, &d) in src.iter().zip(&dst) {
            if s as usize >= num_nodes || d as usize >= num_nodes {
                return Err(Error::Graph(format!(
                    "edge ({s}, {d}) out of range for {num_nodes} nodes"
                )));
            }
        }
        let sort_order = detect_sort_order(&src, &dst);
        Ok(Self {
            src,
            dst,
            num_nodes,
            sort_order,
            is_undirected: false,
            csr_cache: OnceLock::new(),
            csc_cache: OnceLock::new(),
        })
    }

    /// Like `new` but marks the edge set as symmetric (A = Aᵀ). The caller
    /// asserts symmetry; `debug_assert_undirected` verifies in debug builds.
    pub fn new_undirected(src: Vec<u32>, dst: Vec<u32>, num_nodes: usize) -> Result<Self> {
        let mut e = Self::new(src, dst, num_nodes)?;
        e.is_undirected = true;
        debug_assert!(e.verify_undirected(), "edge set is not symmetric");
        Ok(e)
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    pub fn num_edges(&self) -> usize {
        self.src.len()
    }

    pub fn src(&self) -> &[u32] {
        &self.src
    }

    pub fn dst(&self) -> &[u32] {
        &self.dst
    }

    pub fn sort_order(&self) -> SortOrder {
        self.sort_order
    }

    pub fn is_undirected(&self) -> bool {
        self.is_undirected
    }

    /// True if both caches (or the one needed for undirected) are filled.
    pub fn fully_cached(&self) -> bool {
        if self.is_undirected {
            self.csr_cache.get().is_some() || self.csc_cache.get().is_some()
        } else {
            self.csr_cache.get().is_some() && self.csc_cache.get().is_some()
        }
    }

    /// CSR (grouped by source). Cached after first call.
    ///
    /// For undirected graphs with a filled CSC cache this *reuses* the CSC
    /// arrays (A = Aᵀ), reproducing the paper's "caching the CSR format
    /// becomes unnecessary" optimization.
    pub fn csr(&self) -> &Compressed {
        if self.is_undirected {
            if let Some(csc) = self.csc_cache.get() {
                return csc;
            }
        }
        self.csr_cache
            .get_or_init(|| compress(&self.src, &self.dst, self.num_nodes))
    }

    /// CSC (grouped by destination). Cached after first call.
    pub fn csc(&self) -> &Compressed {
        if self.is_undirected {
            if let Some(csr) = self.csr_cache.get() {
                return csr;
            }
        }
        self.csc_cache
            .get_or_init(|| compress(&self.dst, &self.src, self.num_nodes))
    }

    /// Out-degree of every node (scan; does not require the CSR cache).
    pub fn out_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.num_nodes];
        for &s in &self.src {
            deg[s as usize] += 1;
        }
        deg
    }

    /// In-degree of every node.
    pub fn in_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.num_nodes];
        for &d in &self.dst {
            deg[d as usize] += 1;
        }
        deg
    }

    /// Return a copy sorted by destination (enables the fused segmented-
    /// aggregation message-passing path). `perm[i]` gives, for position `i`
    /// of the sorted edge list, the originating COO edge id.
    pub fn sorted_by_dst(&self) -> (EdgeIndex, Vec<u32>) {
        let mut perm: Vec<u32> = (0..self.num_edges() as u32).collect();
        perm.sort_by_key(|&i| (self.dst[i as usize], self.src[i as usize]));
        let src = perm.iter().map(|&i| self.src[i as usize]).collect();
        let dst = perm.iter().map(|&i| self.dst[i as usize]).collect();
        let mut e = EdgeIndex::new(src, dst, self.num_nodes).expect("valid by construction");
        e.is_undirected = self.is_undirected;
        (e, perm)
    }

    /// Symmetrize: add reverse edges (deduplicated) and mark undirected.
    pub fn to_undirected(&self) -> EdgeIndex {
        use std::collections::HashSet;
        let mut seen: HashSet<(u32, u32)> = HashSet::with_capacity(self.num_edges() * 2);
        let mut src = Vec::with_capacity(self.num_edges() * 2);
        let mut dst = Vec::with_capacity(self.num_edges() * 2);
        for (&s, &d) in self.src.iter().zip(&self.dst) {
            for (a, b) in [(s, d), (d, s)] {
                if seen.insert((a, b)) {
                    src.push(a);
                    dst.push(b);
                }
            }
        }
        let mut e = EdgeIndex::new(src, dst, self.num_nodes).expect("valid by construction");
        e.is_undirected = true;
        e
    }

    /// O(E log E) symmetry check (debug / test helper).
    pub fn verify_undirected(&self) -> bool {
        let mut fwd: Vec<(u32, u32)> = self.src.iter().cloned().zip(self.dst.iter().cloned()).collect();
        let mut bwd: Vec<(u32, u32)> = self.dst.iter().cloned().zip(self.src.iter().cloned()).collect();
        fwd.sort_unstable();
        bwd.sort_unstable();
        fwd == bwd
    }
}

fn detect_sort_order(src: &[u32], dst: &[u32]) -> SortOrder {
    if src.windows(2).all(|w| w[0] <= w[1]) {
        SortOrder::ByRow
    } else if dst.windows(2).all(|w| w[0] <= w[1]) {
        SortOrder::ByCol
    } else {
        SortOrder::None
    }
}

/// Counting-sort compression of COO into indptr/indices/perm, grouping by
/// `group` (CSR: group = src; CSC: group = dst). O(N + E), stable.
fn compress(group: &[u32], other: &[u32], num_nodes: usize) -> Compressed {
    let mut indptr = vec![0usize; num_nodes + 1];
    for &g in group {
        indptr[g as usize + 1] += 1;
    }
    for i in 0..num_nodes {
        indptr[i + 1] += indptr[i];
    }
    let mut cursor = indptr.clone();
    let mut indices = vec![0u32; group.len()];
    let mut perm = vec![0u32; group.len()];
    for (e, (&g, &o)) in group.iter().zip(other).enumerate() {
        let pos = cursor[g as usize];
        indices[pos] = o;
        perm[pos] = e as u32;
        cursor[g as usize] += 1;
    }
    Compressed { indptr, indices, perm }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> EdgeIndex {
        // 0->1, 0->2, 1->2, 2->0
        EdgeIndex::new(vec![0, 0, 1, 2], vec![1, 2, 2, 0], 3).unwrap()
    }

    #[test]
    fn validates_ranges_and_lengths() {
        assert!(EdgeIndex::new(vec![0], vec![5], 3).is_err());
        assert!(EdgeIndex::new(vec![0, 1], vec![0], 3).is_err());
    }

    #[test]
    fn detects_sort_order() {
        assert_eq!(toy().sort_order(), SortOrder::ByRow);
        let bycol = EdgeIndex::new(vec![2, 0, 1], vec![0, 1, 2], 3).unwrap();
        assert_eq!(bycol.sort_order(), SortOrder::ByCol);
        let none = EdgeIndex::new(vec![2, 0, 1], vec![1, 2, 0], 3).unwrap();
        assert_eq!(none.sort_order(), SortOrder::None);
    }

    #[test]
    fn csr_groups_by_source() {
        let e = toy();
        let csr = e.csr();
        assert_eq!(csr.indptr, vec![0, 2, 3, 4]);
        assert_eq!(csr.neighbors(0), &[1, 2]);
        assert_eq!(csr.neighbors(1), &[2]);
        assert_eq!(csr.neighbors(2), &[0]);
        assert_eq!(csr.edge_ids(0), &[0, 1]);
    }

    #[test]
    fn csc_groups_by_destination() {
        let e = toy();
        let csc = e.csc();
        assert_eq!(csc.neighbors(0), &[2]); // in-neighbors of 0
        assert_eq!(csc.neighbors(2), &[0, 1]);
        assert_eq!(csc.edge_ids(2), &[1, 2]);
    }

    #[test]
    fn caches_are_reused() {
        let e = toy();
        let p1 = e.csr() as *const Compressed;
        let p2 = e.csr() as *const Compressed;
        assert_eq!(p1, p2);
        assert!(!e.fully_cached());
        e.csc();
        assert!(e.fully_cached());
    }

    #[test]
    fn undirected_shares_one_cache() {
        let e = toy().to_undirected();
        assert!(e.is_undirected());
        assert!(e.verify_undirected());
        let csc = e.csc() as *const Compressed;
        // CSR on an undirected graph must reuse the CSC arrays.
        let csr = e.csr() as *const Compressed;
        assert_eq!(csc, csr);
        assert!(e.fully_cached());
    }

    #[test]
    fn csr_csc_consistent_with_coo() {
        let e = toy();
        let csr = e.csr();
        let mut rebuilt: Vec<(u32, u32)> = Vec::new();
        for v in 0..e.num_nodes() {
            for &n in csr.neighbors(v) {
                rebuilt.push((v as u32, n));
            }
        }
        let mut orig: Vec<(u32, u32)> =
            e.src().iter().cloned().zip(e.dst().iter().cloned()).collect();
        orig.sort_unstable();
        rebuilt.sort_unstable();
        assert_eq!(orig, rebuilt);
    }

    #[test]
    fn sorted_by_dst_permutation_is_consistent() {
        let e = toy();
        let (s, perm) = e.sorted_by_dst();
        assert!(s.dst().windows(2).all(|w| w[0] <= w[1]));
        for (i, &p) in perm.iter().enumerate() {
            assert_eq!(s.src()[i], e.src()[p as usize]);
            assert_eq!(s.dst()[i], e.dst()[p as usize]);
        }
        assert_eq!(s.sort_order(), SortOrder::ByCol);
    }

    #[test]
    fn degrees() {
        let e = toy();
        assert_eq!(e.out_degrees(), vec![2, 1, 1]);
        assert_eq!(e.in_degrees(), vec![1, 1, 2]);
    }

    #[test]
    fn to_undirected_dedups() {
        // 0->1 plus 1->0 already present: symmetrizing must not duplicate.
        let e = EdgeIndex::new(vec![0, 1], vec![1, 0], 2).unwrap();
        let u = e.to_undirected();
        assert_eq!(u.num_edges(), 2);
    }
}
