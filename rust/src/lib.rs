//! # pyg2 — PyG 2.0 reproduction in Rust + JAX + Pallas
//!
//! A three-layer reproduction of *"PyG 2.0: Scalable Learning on Real
//! World Graphs"* (Fey et al., 2025):
//!
//! * **Layer 3 (this crate)** — the scalable graph infrastructure:
//!   [`graph::EdgeIndex`] with cached CSR/CSC, [`storage`] feature/graph
//!   stores, multi-threaded [`sampler`]s (homogeneous / heterogeneous /
//!   temporal / bulk), the [`loader`] pipeline with backpressure,
//!   [`partition`]ing + [`dist`]ributed simulation with out-of-core
//!   [`persist`] partition bundles, and post-processing
//!   ([`explain`], [`metrics`], [`rag`]).
//! * **Layer 2 (python/compile/model.py)** — JAX GNNs (GCN, SAGE, GIN,
//!   GAT, EdgeCNN) AOT-lowered to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for segmented
//!   aggregation, grouped matmul and SpMM, verified against pure-jnp
//!   oracles.
//!
//! Python runs once at build time (`make artifacts`); the [`runtime`]
//! loads the HLO artifacts through PJRT and executes them from pure Rust.

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod explain;
pub mod metrics;
pub mod rag;
pub mod rdl;
pub mod dist;
pub mod loader;
pub mod nn;
pub mod obs;
pub mod partition;
pub mod persist;
pub mod runtime;
pub mod sampler;
pub mod storage;
pub mod error;
pub mod graph;
pub mod tensor;
pub mod util;

pub use error::{Error, Result};

/// Crate version string.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
