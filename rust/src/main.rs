//! pyg2 launcher: the leader entrypoint tying config, data, loader,
//! runtime and post-processing together behind a CLI.

use pyg2::cli::{Args, USAGE};
use pyg2::config::RunConfig;
use pyg2::coordinator::{default_loader, RunMode, Trainer};
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::explain::{ExplainAlgorithm, Explainer};
use pyg2::rag::GraphRag;
use pyg2::runtime::Engine;

fn main() {
    pyg2::util::logging::init();
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_str() {
        "train" => cmd_train(&args),
        "partition" => cmd_partition(&args),
        "dist" => with_metrics(&args, cmd_dist),
        "dist-worker" => with_metrics(&args, cmd_dist_worker),
        "serve-dist" => with_metrics(&args, cmd_serve_dist),
        "obs-check" => cmd_obs_check(&args),
        "explain" => cmd_explain(&args),
        "rag" => cmd_rag(&args),
        "info" => cmd_info(&args),
        "" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Run `cmd` under the `--metrics-out` / `--metrics-every` telemetry
/// knobs: parse them, enable span tracing and start the JSONL exporter
/// when requested, and finish the exporter (end-of-run snapshot) after
/// the command returns. On a command error the exporter's drop still
/// writes a best-effort final report.
fn with_metrics(args: &Args, cmd: fn(&Args) -> pyg2::Result<()>) -> pyg2::Result<()> {
    let metrics = pyg2::cli::MetricsOpts::from_args(args).map_err(pyg2::error::Error::Config)?;
    let exporter = metrics.start()?;
    let result = cmd(args);
    if result.is_ok() {
        if let Some(ex) = exporter {
            ex.finish()?;
        }
    }
    result
}

/// Validate a JSONL telemetry file (`pyg2 obs-check FILE`) — what CI
/// runs on every `--metrics-out` artifact before uploading it.
fn cmd_obs_check(args: &Args) -> pyg2::Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| pyg2::error::Error::Config("usage: pyg2 obs-check FILE".to_string()))?;
    let n = pyg2::obs::check_file(std::path::Path::new(path))?;
    println!("{path}: {n} telemetry snapshots ok");
    Ok(())
}

fn load_config(args: &Args) -> pyg2::Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(path)?,
        None => RunConfig::default(),
    };
    // CLI flags override the file.
    if let Some(a) = args.get("arch") {
        cfg.train.arch = a.to_string();
    }
    if let Some(m) = args.get("mode") {
        cfg.train.mode = if m == "eager" { RunMode::Eager } else { RunMode::Compiled };
    }
    if args.get_bool("trim") {
        cfg.train.trim = true;
    }
    cfg.train.epochs = args.get_usize("epochs", cfg.train.epochs);
    cfg.loader.num_workers = args.get_usize("workers", cfg.loader.num_workers);
    Ok(cfg)
}

fn make_graph(engine: &Engine, cfg: &RunConfig) -> pyg2::Result<pyg2::graph::Graph> {
    let b = &engine.manifest().bucket;
    sbm::generate(&SbmConfig {
        num_nodes: cfg.data.num_nodes,
        num_blocks: b.c,
        feature_dim: b.f,
        feature_signal: cfg.data.feature_signal,
        seed: cfg.data.seed,
        ..Default::default()
    })
}

fn cmd_train(args: &Args) -> pyg2::Result<()> {
    let cfg = load_config(args)?;
    let engine = Engine::load(&cfg.artifacts_dir)?;
    let graph = make_graph(&engine, &cfg)?;
    log::info!(
        "training {} ({:?}, trim={}) on SBM n={} e={}",
        cfg.train.arch,
        cfg.train.mode,
        cfg.train.trim,
        graph.num_nodes(),
        graph.num_edges()
    );
    let seeds: Vec<u32> = (0..cfg.loader.num_seeds.min(graph.num_nodes()) as u32).collect();
    let loader = default_loader(&engine, &graph, seeds, cfg.loader.num_workers);
    let report = Trainer::new(&engine, cfg.train.clone()).train(&loader)?;
    println!(
        "done: {} steps, final loss {:.4}, recent accuracy {:.3}, mean step {:.2} ms",
        report.history.len(),
        report.final_loss(),
        report.recent_accuracy(10),
        report.mean_step_ms()
    );
    Ok(())
}

fn cmd_partition(args: &Args) -> pyg2::Result<()> {
    let nodes = args.get_usize("nodes", 5000);
    let parts = args.get_usize("parts", 4);

    // Typed partitioning: the user/item/tag hetero SBM `pyg2 dist
    // --hetero` loads, LDG-partitioned per node type and optionally
    // materialized as a typed partition bundle.
    if args.get_bool("hetero") {
        use pyg2::datasets::hetero::{self, HeteroSbmConfig};
        let g = hetero::generate(&HeteroSbmConfig {
            num_users: nodes,
            num_items: nodes * 2 / 3,
            num_tags: nodes / 10,
            seed: 0,
            ..Default::default()
        })?;
        let tp = pyg2::partition::TypedPartitioning::ldg_hetero(&g, parts, 1.1)?;
        for (et, cut) in tp.cut_edges(&g)? {
            println!("edge type {}: {cut} cut edges", et.key());
        }
        if let Some(dir) = args.get("write") {
            let bundle = pyg2::persist::write_bundle_hetero(dir, &g, &tp)?;
            report_bundle(&bundle);
        }
        return Ok(());
    }

    let g = sbm::generate(&SbmConfig { num_nodes: nodes, seed: 0, ..Default::default() })?;
    let p = pyg2::partition::ldg_partition(&g.edge_index, parts, 1.1)?;
    let r = pyg2::partition::random_partition(nodes, parts, 1);
    println!(
        "LDG:    edge-cut {:.3}, balance {:.3}, sizes {:?}",
        p.edge_cut(&g.edge_index),
        p.balance(),
        p.part_sizes()
    );
    println!(
        "random: edge-cut {:.3}, balance {:.3}",
        r.edge_cut(&g.edge_index),
        r.balance()
    );
    if let Some(dir) = args.get("write") {
        let bundle = pyg2::persist::write_bundle(dir, &g, &p)?;
        report_bundle(&bundle);
    }
    Ok(())
}

/// Summarize a just-written partition bundle: per-type/per-relation
/// shard layout plus total bytes on disk.
fn report_bundle(bundle: &pyg2::persist::Bundle) {
    let m = bundle.manifest();
    println!(
        "wrote bundle {} ({} partitions, {} node types, {} edge types)",
        bundle.dir().display(),
        m.num_parts,
        m.node_types.len(),
        m.edge_types.len()
    );
    for nt in &m.node_types {
        println!("  node type {}: {} nodes, {} feature shards", nt.name, nt.num_nodes, m.num_parts);
    }
    for et in &m.edge_types {
        println!(
            "  edge type {}: {} edges, {} adjacency shards",
            et.ty.key(),
            et.num_edges,
            m.num_parts
        );
    }
    let mut bytes = 0u64;
    let mut stack = vec![bundle.dir().to_path_buf()];
    while let Some(d) = stack.pop() {
        if let Ok(entries) = std::fs::read_dir(&d) {
            for e in entries.flatten() {
                let path = e.path();
                if path.is_dir() {
                    stack.push(path);
                } else if let Ok(meta) = e.metadata() {
                    bytes += meta.len();
                }
            }
        }
    }
    println!("  {bytes} bytes on disk");
}

fn cmd_dist(args: &Args) -> pyg2::Result<()> {
    let nodes = args.get_usize("nodes", 5000);
    let parts = args.get_usize("parts", 4);
    let batch = args.get_usize("batch", 64);
    let workers = args.get_usize("workers", 2);
    let epochs = args.get_usize("epochs", 1);
    let mount = pyg2::cli::MountOpts::from_args(args).map_err(pyg2::error::Error::Config)?;
    let opts = pyg2::coordinator::DistOptions {
        halo_cache: args.get_bool("halo-cache"),
        async_fetch: args.get_bool("async"),
        async_workers: args.get_usize("async-workers", 0),
        latency: std::time::Duration::from_micros(args.get_usize("latency-us", 0) as u64),
        prefetch: mount.prefetch,
        io_backend: mount.io_backend,
        halo_adj: mount.halo_adj,
    };
    if mount.mounted() {
        return cmd_dist_mounted(args, &mount, batch, workers, epochs, opts);
    }
    if args.get_bool("hetero") {
        return cmd_dist_hetero(args, parts, batch, workers, epochs, opts);
    }
    let g = sbm::generate(&SbmConfig { num_nodes: nodes, seed: 0, ..Default::default() })?;
    let p = pyg2::partition::ldg_partition(&g.edge_index, parts, 1.1)?;
    let cfg = pyg2::loader::LoaderConfig {
        batch_size: batch,
        num_workers: workers,
        ..Default::default()
    };

    // Multi-rank simulation: one loader per rank over its own seed
    // shard, aggregated into the rank × partition traffic matrix.
    if let Some(ranks) = args.get("ranks") {
        let ranks: usize = ranks
            .parse()
            .map_err(|_| pyg2::error::Error::Config(format!("bad --ranks {ranks}")))?;
        log::info!(
            "multi-rank dist: {ranks} ranks over {parts} partitions (edge-cut {:.3})",
            p.edge_cut(&g.edge_index)
        );
        let t0 = std::time::Instant::now();
        let report =
            pyg2::coordinator::multi_rank_epoch(&g, &p, ranks, &cfg, opts, epochs as u64)?;
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "multi-rank dist: {} batches / {} sampled nodes in {secs:.2}s",
            report.batches, report.sampled_nodes
        );
        println!("traffic matrix (msgs(payload rows) per rank -> partition):");
        println!("{}", report.matrix);
        println!("{}", report.skew());
        for (part, (in_e, out_e)) in report.shard_edges.iter().enumerate() {
            println!("partition {part}: {in_e} in-edges / {out_e} out-edges stored");
        }
        for (rank, stats) in report.cache.iter().enumerate() {
            if let Some(stats) = stats {
                println!("rank {rank} halo cache: {stats}");
            }
        }
        return Ok(());
    }

    let loader = pyg2::coordinator::partitioned_loader_with(
        &g,
        &p,
        0,
        (0..nodes as u32).collect(),
        cfg,
        opts,
    )?;
    log::info!(
        "dist loading over {parts} partitions (edge-cut {:.3}): n={nodes} e={}",
        p.edge_cut(&g.edge_index),
        g.num_edges()
    );
    let t0 = std::time::Instant::now();
    let mut batches = 0usize;
    let mut sampled_nodes = 0usize;
    for epoch in 0..epochs {
        for b in loader.iter_epoch(epoch as u64) {
            let b = b?;
            batches += 1;
            sampled_nodes += b.num_real_nodes();
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let stats = loader.router_stats();
    println!(
        "dist: {batches} batches / {sampled_nodes} sampled nodes in {secs:.2}s \
         ({:.0} nodes/s)",
        sampled_nodes as f64 / secs
    );
    println!("cross-partition traffic: {stats}");
    if let Some(cache) = loader.cache_stats() {
        println!("halo cache: {cache}");
    }
    Ok(())
}

/// The out-of-core distributed pipeline (`pyg2 dist --mount DIR`): run
/// the loader over a partition bundle written by `pyg2 partition
/// --write DIR`, with the topology served from binary adjacency shards
/// and feature rows demand-paged from disk through the bounded LRU —
/// the original dataset is never reloaded. Typed bundles route through
/// the hetero loader automatically; `--ranks N` runs the multi-rank
/// simulation over homogeneous bundles.
fn cmd_dist_mounted(
    args: &Args,
    mount: &pyg2::cli::MountOpts,
    batch: usize,
    workers: usize,
    epochs: usize,
    opts: pyg2::coordinator::DistOptions,
) -> pyg2::Result<()> {
    let dir = mount.dir.as_deref().expect("cmd_dist_mounted called with --mount");
    let bundle = pyg2::persist::Bundle::open(dir)?;
    let rank = mount.rank;
    let lru = mount.lru();
    log::info!(
        "mounted bundle {dir}: {} partitions, {} node types, {} edge types, \
         cache budget {} bytes ({} rows / {} adjacency / {} halo tier{}{}), \
         {} backend{}",
        bundle.num_parts(),
        bundle.manifest().node_types.len(),
        bundle.manifest().edge_types.len(),
        lru.capacity_bytes,
        lru.row_budget(),
        lru.adj_budget(),
        lru.halo_budget(),
        if lru.page_adjacency { ", adjacency demand-paged" } else { "" },
        if mount.halo_adj { ", halo in-lists replicated" } else { "" },
        mount.io_backend,
        if mount.prefetch { ", pipeline prefetch" } else { "" }
    );

    // Real multi-process ranks: delegate to the launcher, which spawns
    // `pyg2 dist-worker` processes over this same bundle.
    if let Some(procs) = args.get("procs") {
        let procs: usize = procs
            .parse()
            .map_err(|_| pyg2::error::Error::Config(format!("bad --procs {procs}")))?;
        return cmd_dist_procs(args, dir, procs);
    }

    if let Some(ranks) = args.get("ranks") {
        let ranks: usize = ranks
            .parse()
            .map_err(|_| pyg2::error::Error::Config(format!("bad --ranks {ranks}")))?;
        let cfg = pyg2::loader::LoaderConfig {
            batch_size: batch,
            num_workers: workers,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let report = pyg2::coordinator::multi_rank_epoch_mounted(
            &bundle,
            ranks,
            &cfg,
            opts,
            lru,
            epochs as u64,
        )?;
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "mounted multi-rank dist: {} batches / {} sampled nodes in {secs:.2}s",
            report.batches, report.sampled_nodes
        );
        println!("traffic matrix (msgs(payload rows) per rank -> partition):");
        println!("{}", report.matrix);
        println!("{}", report.skew());
        for (r, rc) in report.row_cache.iter().enumerate() {
            println!("rank {r} row cache: {rc}");
            println!("rank {r} feature disk reads: {}", report.disk_reads[r]);
            if let Some(ac) = &report.adj_cache[r] {
                println!("rank {r} adjacency cache: {ac}");
                println!("rank {r} adjacency disk reads: {}", report.adj_disk_reads[r]);
                println!("rank {r} cache budget split: {}", report.mount_cache_stats(r));
            }
            if let Some(ht) = &report.adj_halo[r] {
                println!("rank {r} adjacency halo tier: {ht}");
            }
            if let Some(h) = &report.halo[r] {
                println!("rank {r} halo cache: {h}");
            }
            if let Some(pf) = &report.prefetch[r] {
                println!(
                    "rank {r} prefetch: {} batches warmed, {} failed, {} halo skips",
                    pf.scheduled, pf.failed, pf.skipped
                );
            }
        }
        return Ok(());
    }

    let mut batches = 0usize;
    let mut sampled_nodes = 0usize;
    let t0 = std::time::Instant::now();
    if bundle.is_typed() {
        let seed_type = match args.get("seed-type") {
            Some(st) => st.to_string(),
            None => bundle.manifest().node_types[0].name.clone(),
        };
        let seeds: Vec<u32> = (0..bundle.node_type(&seed_type)?.num_nodes as u32).collect();
        let cfg = pyg2::loader::HeteroLoaderConfig {
            batch_size: batch,
            num_workers: workers,
            ..Default::default()
        };
        let loader = pyg2::coordinator::hetero_mounted_loader(
            &bundle, rank, &seed_type, seeds, cfg, opts, lru,
        )?;
        for epoch in 0..epochs {
            for b in loader.iter_epoch(epoch as u64) {
                let b = b?;
                batches += 1;
                sampled_nodes += b.total_nodes();
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "mounted hetero dist: {batches} batches / {sampled_nodes} sampled nodes \
             in {secs:.2}s ({:.0} nodes/s)",
            sampled_nodes as f64 / secs
        );
        println!("cross-partition traffic: {}", loader.router_stats());
        for (et, stats) in loader.edge_traffic() {
            println!("edge type {}: {stats}", et.key());
        }
        for (nt, stats) in loader.cache_stats() {
            println!("{nt} halo cache: {stats}");
        }
        print_mount_io(loader.features(), loader.graph());
        print_prefetch(loader.prefetch_stats());
    } else {
        let n = bundle.node_type(pyg2::storage::DEFAULT_GROUP)?.num_nodes;
        let cfg = pyg2::loader::LoaderConfig {
            batch_size: batch,
            num_workers: workers,
            ..Default::default()
        };
        let loader = pyg2::coordinator::mounted_loader(
            &bundle,
            rank,
            (0..n as u32).collect(),
            cfg,
            opts,
            lru,
        )?;
        for epoch in 0..epochs {
            for b in loader.iter_epoch(epoch as u64) {
                let b = b?;
                batches += 1;
                sampled_nodes += b.num_real_nodes();
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "mounted dist: {batches} batches / {sampled_nodes} sampled nodes in {secs:.2}s \
             ({:.0} nodes/s)",
            sampled_nodes as f64 / secs
        );
        println!("cross-partition traffic: {}", loader.router_stats());
        if let Some(cache) = loader.cache_stats() {
            println!("halo cache: {cache}");
        }
        print_mount_io(loader.features(), loader.graph());
        print_prefetch(loader.prefetch_stats());
    }
    Ok(())
}

/// Loader/mount flags every `dist-worker` must see verbatim so its
/// batch stream reproduces the launcher's knobs (launcher-only and
/// per-worker flags — `--procs`, `--ranks`, `--rank`, `--mount`,
/// `--sock-dir`, `--metrics-*` — are deliberately absent).
fn forward_worker_flags(args: &Args) -> Vec<String> {
    const FORWARD: [&str; 15] = [
        "batch",
        "workers",
        "epochs",
        "cache-mb",
        "adj-cache-mb",
        "page-adj",
        "halo-adj",
        "halo-adj-mb",
        "prefetch",
        "io-backend",
        "seed-type",
        "halo-cache",
        "async",
        "async-workers",
        "fail-after-batches",
    ];
    let mut out = Vec::new();
    for f in FORWARD {
        if let Some(v) = args.get(f) {
            out.push(format!("--{f}={v}"));
        }
    }
    out.push(format!("--deadline-secs={}", args.get_usize("deadline-secs", 120)));
    out
}

/// `pyg2 dist --procs N --mount DIR`: spawn N real worker processes
/// over the shared bundle and aggregate their reports.
fn cmd_dist_procs(args: &Args, dir: &str, procs: usize) -> pyg2::Result<()> {
    let cfg = pyg2::coordinator::DistProcsConfig {
        bin: std::env::current_exe()?,
        mount: std::path::PathBuf::from(dir),
        procs,
        forward: forward_worker_flags(args),
        deadline: std::time::Duration::from_secs(args.get_usize("deadline-secs", 120) as u64),
        metrics_out: args.get("metrics-out").map(std::path::PathBuf::from),
    };
    let report = pyg2::coordinator::run_parent(&cfg)?;
    println!(
        "multi-process dist: {} batches / {} sampled nodes across {procs} workers \
         in {:.2}s",
        report.batches, report.sampled_nodes, report.wall_seconds
    );
    println!("traffic matrix (msgs(payload rows) per rank -> partition):");
    println!("{}", report.matrix);
    println!("{}", report.skew());
    let total: f64 = report.rank_seconds.iter().sum();
    println!(
        "measured overlap: sum(rank secs) {total:.2} / wall {:.2} = {:.2}x",
        report.wall_seconds,
        report.overlap()
    );
    if let Some(m) = &report.merged_metrics {
        println!("worker telemetry merged into {}", m.display());
    }
    Ok(())
}

/// One rank of a `pyg2 dist --procs N` run. Spawned by the launcher —
/// it mounts the shared bundle read-only, serves its peers' feature
/// fetches over its unix socket, and reports back over the control
/// socket.
fn cmd_dist_worker(args: &Args) -> pyg2::Result<()> {
    let mount = pyg2::cli::MountOpts::from_args(args).map_err(pyg2::error::Error::Config)?;
    let dir = mount
        .dir
        .as_deref()
        .ok_or_else(|| pyg2::error::Error::Config("dist-worker requires --mount DIR".into()))?;
    let sock_dir = args
        .get("sock-dir")
        .ok_or_else(|| pyg2::error::Error::Config("dist-worker requires --sock-dir DIR".into()))?;
    let opts = pyg2::coordinator::DistOptions {
        halo_cache: args.get_bool("halo-cache"),
        async_fetch: args.get_bool("async"),
        async_workers: args.get_usize("async-workers", 0),
        latency: std::time::Duration::from_micros(args.get_usize("latency-us", 0) as u64),
        prefetch: mount.prefetch,
        io_backend: mount.io_backend,
        halo_adj: mount.halo_adj,
    };
    let wc = pyg2::coordinator::WorkerConfig {
        rank: mount.rank,
        world: args.get_usize("world", 0),
        sock_dir: std::path::PathBuf::from(sock_dir),
        epochs: args.get_usize("epochs", 1) as u64,
        batch_size: args.get_usize("batch", 64),
        num_workers: args.get_usize("workers", 2),
        seed_type: args.get("seed-type").map(str::to_string),
        opts,
        lru: mount.lru(),
        deadline: std::time::Duration::from_secs(args.get_usize("deadline-secs", 120) as u64),
        fail_after: args.get("fail-after-batches").and_then(|v| v.parse().ok()),
    };
    let bundle = pyg2::persist::Bundle::open(dir)?;
    pyg2::coordinator::run_worker(&bundle, &wc)
}

/// Pipeline-prefetch counters (installed by `--prefetch`), with the
/// row/adjacency cache provenance that tells how much warming paid off.
fn print_prefetch(stats: Option<pyg2::dist::PrefetchStats>) {
    if let Some(pf) = stats {
        println!(
            "prefetch: {} batches warmed, {} failed, {} halo skips",
            pf.scheduled, pf.failed, pf.skipped
        );
    }
}

/// Shared mount I/O report: the halo / row-cache / adjacency-cache split
/// of the budget plus the positioned-read counters of both paged paths.
fn print_mount_io(
    fs: &pyg2::dist::PartitionedFeatureStore,
    gs: &pyg2::dist::PartitionedGraphStore,
) {
    if let Some(rc) = fs.row_cache_stats() {
        println!("row cache: {rc}");
        if let Some(ac) = gs.adj_cache_stats() {
            println!("adjacency cache: {ac}");
            let halo = gs.adj_halo_stats();
            if let Some(ht) = &halo {
                println!("adjacency halo tier: {ht}");
            }
            let split = pyg2::persist::MountCacheStats { rows: rc, adj: Some(ac), halo };
            println!("cache budget split: {split}");
        }
    }
    if let Some(reads) = fs.disk_reads() {
        println!("feature disk reads: {reads}");
    }
    if let Some(reads) = gs.adj_disk_reads() {
        println!("adjacency disk reads: {reads}");
    }
}

/// Distributed inference serving (`pyg2 serve-dist`): N server workers
/// pull dynamic batches from one shared admission queue over the
/// partitioned stores — an in-memory SBM partitioning by default, or a
/// `--mount`ed bundle (optionally with `--page-adj` demand-paged
/// adjacency) — while a closed-loop Zipf-skewed client fleet drives
/// traffic and reports p50/p95/p99 latency plus throughput.
fn cmd_serve_dist(args: &Args) -> pyg2::Result<()> {
    use pyg2::coordinator::{run_traffic, DistInferenceServer, ServeDistConfig, TrafficConfig};
    use pyg2::nn::NodeClassifier;
    use pyg2::storage::FeatureKey;
    use std::sync::Arc;
    use std::time::Duration;

    let mount = pyg2::cli::MountOpts::from_args(args).map_err(pyg2::error::Error::Config)?;
    let opts = pyg2::coordinator::DistOptions {
        halo_cache: args.get_bool("halo-cache"),
        async_fetch: args.get_bool("async"),
        async_workers: args.get_usize("async-workers", 0),
        latency: Duration::from_micros(args.get_usize("latency-us", 0) as u64),
        prefetch: mount.prefetch,
        io_backend: mount.io_backend,
        halo_adj: mount.halo_adj,
    };
    let cfg = ServeDistConfig {
        max_batch: args.get_usize("max-batch", 16),
        max_wait: Duration::from_millis(args.get_usize("max-wait-ms", 2) as u64),
        workers: args.get_usize("workers", 2),
        prefetch: mount.prefetch,
        ..Default::default()
    };

    // Assemble the stores + labels from either backing; the server is
    // oblivious to which one it got.
    let (gs, fs, labels, num_nodes) = if let Some(dir) = mount.dir.as_deref() {
        let bundle = pyg2::persist::Bundle::open(dir)?;
        let n = bundle.node_type(pyg2::storage::DEFAULT_GROUP)?.num_nodes;
        let (gs, fs, labels) =
            pyg2::coordinator::mounted_stores(&bundle, mount.rank, opts, mount.lru())?;
        let labels = labels.ok_or_else(|| {
            pyg2::error::Error::Config(format!(
                "bundle {dir} has no labels; serve-dist fits its classifier from them"
            ))
        })?;
        (gs, fs, labels, n)
    } else {
        let nodes = args.get_usize("nodes", 5000);
        let parts = args.get_usize("parts", 4);
        let g = sbm::generate(&SbmConfig { num_nodes: nodes, seed: 0, ..Default::default() })?;
        let p = pyg2::partition::ldg_partition(&g.edge_index, parts, 1.1)?;
        let (gs, fs) = pyg2::coordinator::partitioned_stores(&g, &p, 0, opts)?;
        let labels = g.y.clone().expect("SBM graphs carry labels");
        (gs, fs, labels, nodes)
    };

    let num_classes = (labels.iter().copied().max().unwrap_or(0).max(0) + 1) as usize;
    let model = Arc::new(NodeClassifier::fit(
        fs.as_ref(),
        &FeatureKey::default_x(),
        &labels,
        num_classes,
    )?);
    // Fitting paged every labeled row through the mounted LRU; zero the
    // I/O and router ledgers so the report reflects serving alone.
    fs.reset_io_stats();
    gs.reset_adj_io_stats();
    gs.typed_router().reset_with(fs.typed_router());

    log::info!(
        "serve-dist: {} workers, max_batch {}, max_wait {:?}, {num_classes} classes, \
         {num_nodes} servable nodes",
        cfg.workers,
        cfg.max_batch,
        cfg.max_wait
    );
    let workers = cfg.workers;
    let server = DistInferenceServer::spawn(Arc::clone(&gs), Arc::clone(&fs), model, cfg)?;
    let traffic = TrafficConfig {
        clients: args.get_usize("clients", 4),
        requests_per_client: args.get_usize("requests", 64),
        zipf_exponent: args.get_f64("zipf", 1.1),
        budget: args
            .get("budget-ms")
            .and_then(|v| v.parse::<u64>().ok())
            .map(Duration::from_millis),
        seed: args.get_usize("seed", 0) as u64,
    };
    let report = run_traffic(&server, num_nodes, &traffic);
    let stats = server.stats();
    println!(
        "serve-dist ({workers} workers, {} clients x {} reqs, zipf {:.2}): {report}",
        traffic.clients, traffic.requests_per_client, traffic.zipf_exponent
    );
    println!(
        "server: {} requests / {} batches (mean batch {:.2}), \
         {} deadline-rejected, {} errors",
        stats.requests,
        stats.batches,
        stats.mean_batch_size(),
        stats.deadline_rejected,
        stats.errors
    );
    println!(
        "cross-partition traffic: {}",
        gs.typed_router().stats_with(fs.typed_router())
    );
    print_mount_io(&fs, &gs);
    print_prefetch(server.prefetch_stats());
    if pyg2::obs::enabled() {
        for (stage, h) in pyg2::obs::stage_report() {
            println!(
                "stage {stage}: n={} p50={}us p95={}us p99={}us max={}us",
                h.count, h.p50, h.p95, h.p99, h.max
            );
        }
    }
    Ok(())
}

/// The typed distributed pipeline (`pyg2 dist --hetero`): a
/// user/item/tag hetero SBM partitioned per node type, loaded through
/// `HeteroDistNeighborSampler` + per-type routed feature fetch, with the
/// same `--halo-cache` / `--async` / `--ranks` layers as the
/// homogeneous path.
fn cmd_dist_hetero(
    args: &Args,
    parts: usize,
    batch: usize,
    workers: usize,
    epochs: usize,
    opts: pyg2::coordinator::DistOptions,
) -> pyg2::Result<()> {
    use pyg2::datasets::hetero::{self, HeteroSbmConfig};

    let users = args.get_usize("nodes", 5000);
    let g = hetero::generate(&HeteroSbmConfig {
        num_users: users,
        num_items: users * 2 / 3,
        num_tags: users / 10,
        seed: 0,
        ..Default::default()
    })?;
    let tp = pyg2::partition::TypedPartitioning::ldg_hetero(&g, parts, 1.1)?;
    let cuts = tp.cut_edges(&g)?;
    let cfg = pyg2::loader::HeteroLoaderConfig {
        batch_size: batch,
        num_workers: workers,
        ..Default::default()
    };
    log::info!(
        "hetero dist over {parts} typed partitions: {} node types / {} edge types, \
         {} nodes / {} edges",
        g.num_node_types(),
        g.num_edge_types(),
        g.total_nodes(),
        g.total_edges()
    );
    for (et, cut) in &cuts {
        println!("edge type {}: {cut} cut edges", et.key());
    }

    // Multi-rank simulation: one typed loader per rank over the user
    // seeds it owns, aggregated per node type.
    if let Some(ranks) = args.get("ranks") {
        let ranks: usize = ranks
            .parse()
            .map_err(|_| pyg2::error::Error::Config(format!("bad --ranks {ranks}")))?;
        let t0 = std::time::Instant::now();
        let report = pyg2::coordinator::multi_rank_epoch_hetero(
            &g,
            &tp,
            "user",
            ranks,
            &cfg,
            opts,
            epochs as u64,
        )?;
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "hetero multi-rank dist: {} batches / {} sampled nodes in {secs:.2}s",
            report.batches, report.sampled_nodes
        );
        println!("combined traffic matrix (msgs(payload rows) per rank -> partition):");
        println!("{}", report.matrix);
        println!("{}", report.skew());
        for (nt, m) in &report.per_type {
            println!(
                "node type {nt}: {} remote msgs / {} remote rows",
                m.total_remote_msgs(),
                m.total_remote_rows()
            );
        }
        for (et, stats) in &report.edge_traffic {
            println!("edge type {}: {stats}", et.key());
        }
        for (rank, stats) in report.cache.iter().enumerate() {
            for (nt, s) in stats {
                println!("rank {rank} {nt} halo cache: {s}");
            }
        }
        return Ok(());
    }

    let seeds: Vec<u32> = (0..g.num_nodes("user")? as u32).collect();
    let loader =
        pyg2::coordinator::hetero_partitioned_loader_with(&g, &tp, 0, "user", seeds, cfg, opts)?;
    let t0 = std::time::Instant::now();
    let mut batches = 0usize;
    let mut sampled_nodes = 0usize;
    for epoch in 0..epochs {
        for b in loader.iter_epoch(epoch as u64) {
            let b = b?;
            batches += 1;
            sampled_nodes += b.total_nodes();
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "hetero dist: {batches} batches / {sampled_nodes} sampled nodes in {secs:.2}s \
         ({:.0} nodes/s)",
        sampled_nodes as f64 / secs
    );
    println!("cross-partition traffic: {}", loader.router_stats());
    for (et, stats) in loader.edge_traffic() {
        println!("edge type {}: {stats}", et.key());
    }
    for (nt, stats) in loader.cache_stats() {
        println!("{nt} halo cache: {stats}");
    }
    Ok(())
}

fn cmd_explain(args: &Args) -> pyg2::Result<()> {
    let cfg = load_config(args)?;
    let engine = Engine::load(&cfg.artifacts_dir)?;
    let graph = make_graph(&engine, &cfg)?;
    let loader = default_loader(&engine, &graph, (0..256).collect(), cfg.loader.num_workers);
    let mut tcfg = cfg.train.clone();
    tcfg.arch = "gcn".into();
    let report = Trainer::new(&engine, tcfg).train(&loader)?;
    let batch = loader.iter_epoch(1000).next().unwrap()?;
    let explainer = Explainer::new(&engine, "gcn");
    let ex = explainer.explain(&report.final_params, &batch, ExplainAlgorithm::Saliency)?;
    let (fp, fm) = explainer.fidelity(&report.final_params, &batch, &ex, 32)?;
    println!("explained batch: loss {:.4}", ex.loss);
    println!("fidelity+ (drop top-32 edges):    {fp:.3}");
    println!("fidelity- (drop bottom-32 edges): {fm:.3}");
    Ok(())
}

fn cmd_rag(args: &Args) -> pyg2::Result<()> {
    let cfg = load_config(args)?;
    let engine = Engine::load(&cfg.artifacts_dir)?;
    let ds = pyg2::datasets::kgqa::generate(&pyg2::datasets::KgqaConfig {
        num_questions: args.get_usize("questions", 100),
        ..Default::default()
    })?;
    let rag = GraphRag::new(&engine, &ds)?;
    let (mut rag_hits, mut base_hits) = (0, 0);
    for q in &ds.questions {
        if rag.answer(&q.text)? == Some(q.answer) {
            rag_hits += 1;
        }
        if rag.baseline_answer(&q.text) == Some(q.answer) {
            base_hits += 1;
        }
    }
    let n = ds.questions.len();
    println!("KGQA over {n} 2-hop questions:");
    println!("  LLM-only baseline accuracy: {:.1}%", 100.0 * base_hits as f64 / n as f64);
    println!("  GraphRAG accuracy:          {:.1}%", 100.0 * rag_hits as f64 / n as f64);
    Ok(())
}

fn cmd_info(args: &Args) -> pyg2::Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let engine = Engine::load(dir)?;
    let m = engine.manifest();
    println!("pyg2 {} — artifact dir {dir}", pyg2::VERSION);
    println!(
        "bucket: seeds={} fanouts={:?} F={} H={} C={}",
        m.bucket.s, m.bucket.fanouts, m.bucket.f, m.bucket.h, m.bucket.c
    );
    println!("programs: {}", m.programs.len());
    println!("op artifacts: {}", m.ops.len());
    Ok(())
}
