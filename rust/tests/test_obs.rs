//! The observability layer's correctness anchor: registry semantics,
//! span tracing on/off, the JSONL snapshot schema — and the invariant
//! everything else leans on: **telemetry must never change results**.
//! Batch streams (homogeneous + heterogeneous, mounted resident +
//! demand-paged adjacency) and serving predictions must be seed-for-seed
//! identical with `--metrics-out` tracing on or off, because nothing in
//! the obs layer consumes RNG state or reorders pipeline work.
//!
//! The tracing switch is process-global, so every test that flips it
//! serializes on one mutex and restores "off" before releasing it;
//! tests that only read counters need no coordination (counters are
//! always on, and scoped instances get distinct names).

use pyg2::coordinator::{
    hetero_mounted_loader, hetero_partitioned_loader_with, mounted_loader, mounted_stores,
    partitioned_loader_with, DistInferenceServer, DistOptions, ServeDistConfig,
};
use pyg2::datasets::hetero::{self, HeteroSbmConfig};
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::loader::{Batch, HeteroBatch, HeteroLoaderConfig, LoaderConfig};
use pyg2::nn::NodeClassifier;
use pyg2::obs;
use pyg2::partition::{ldg_partition, TypedPartitioning};
use pyg2::persist::{write_bundle, write_bundle_hetero, LruConfig};
use pyg2::sampler::{HeteroSamplerConfig, NeighborSamplerConfig};
use pyg2::storage::FeatureKey;
use pyg2::util::json::Json;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Serializes the tests that flip the process-global tracing switch.
static TRACE_LOCK: Mutex<()> = Mutex::new(());

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("pyg2_test_obs").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn registry_counters_gauges_histograms() {
    let c = obs::counter("test.obs.reg.count");
    c.inc();
    c.add(4);
    assert_eq!(c.get(), 5);
    assert!(Arc::ptr_eq(&c, &obs::counter("test.obs.reg.count")), "one handle per name");
    c.reset();
    assert_eq!(c.get(), 0);

    let g = obs::gauge("test.obs.reg.depth");
    g.add(10);
    g.sub(3);
    assert_eq!(g.get(), 7);
    g.set(-2);
    assert_eq!(g.get(), -2);

    // The pinned quantile contract: nearest-rank over log buckets,
    // reported as the inclusive bucket upper bound.
    let h = obs::histogram("test.obs.reg.lat");
    for v in 1..=1000u64 {
        h.record(v);
    }
    let s = h.snapshot();
    assert_eq!((s.count, s.sum), (1000, 500500));
    assert_eq!((s.p50, s.p99, s.max), (511, 991, 1023));

    let (counters, gauges, hists) = obs::read_all();
    assert!(counters.iter().any(|(k, _)| k == "test.obs.reg.count"));
    assert!(gauges.iter().any(|(k, v)| k == "test.obs.reg.depth" && *v == -2));
    assert!(hists.iter().any(|(k, s)| k == "test.obs.reg.lat" && s.count == 1000));
}

#[test]
fn scoped_instances_keep_distinct_names() {
    let a = obs::Scope::new("test.obs.scope");
    let b = obs::Scope::new("test.obs.scope");
    assert_ne!(a.prefix(), b.prefix(), "second instance must be disambiguated");
    a.counter("hits").add(3);
    b.counter("hits").add(8);
    assert_eq!(a.counter("hits").get(), 3);
    assert_eq!(b.counter("hits").get(), 8);
}

#[test]
fn span_switch_gates_recording() {
    let _guard = TRACE_LOCK.lock().unwrap();
    let h = obs::histogram("trace.obs_gate_us");
    obs::set_enabled(false);
    {
        let s = obs::span("obs_gate");
        assert!(!s.is_live(), "disabled span must be a no-op guard");
    }
    obs::record_stage("obs_gate", 9);
    assert_eq!(h.count(), 0, "disabled tracing must record nothing");

    obs::set_enabled(true);
    {
        let _outer = obs::span("obs_gate");
        drop(obs::span("obs_gate")); // nested same-stage span times itself
    }
    obs::record_stage("obs_gate", 9);
    obs::set_enabled(false);
    assert_eq!(h.count(), 3, "outer + nested + manual all recorded");
    assert!(obs::stage_report().iter().any(|(s, _)| s == "obs_gate"));

    obs::reset_traces();
    assert_eq!(h.count(), 0, "reset_traces zeroes trace.* histograms");
}

#[test]
fn jsonl_snapshot_schema_roundtrips_and_exporter_validates() {
    obs::counter("test.obs.jsonl.c").add(11);
    obs::gauge("test.obs.jsonl.g").set(-4);
    obs::histogram("test.obs.jsonl.h").record(100);

    let line = obs::snapshot_json(2, 55, true).to_string();
    let v = pyg2::util::json::parse(&line).unwrap();
    assert_eq!(v.get("seq").unwrap().as_f64(), Some(2.0));
    assert_eq!(v.get("ts_ms").unwrap().as_f64(), Some(55.0));
    assert_eq!(v.get("final").unwrap().as_bool(), Some(true));
    assert_eq!(v.get("counters").unwrap().get("test.obs.jsonl.c").unwrap().as_f64(), Some(11.0));
    assert_eq!(v.get("gauges").unwrap().get("test.obs.jsonl.g").unwrap().as_f64(), Some(-4.0));
    let h = v.get("histograms").unwrap().get("test.obs.jsonl.h").unwrap();
    for key in ["count", "sum", "p50", "p90", "p95", "p99", "max"] {
        assert!(h.get(key).is_some(), "histogram snapshot missing {key}");
    }

    let dir = tmp("exporter");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("metrics.jsonl");
    let ex = obs::Exporter::start(&path, None).unwrap();
    ex.finish().unwrap();
    assert_eq!(obs::check_file(&path).unwrap(), 1, "one final snapshot");
    std::fs::write(&path, "{\"seq\":0}\n").unwrap();
    assert!(obs::check_file(&path).is_err(), "schema violations must be rejected");
}

fn loader_cfg(workers: usize) -> LoaderConfig {
    LoaderConfig {
        batch_size: 16,
        num_workers: workers,
        shuffle: true,
        seed: 13,
        sampler: NeighborSamplerConfig { fanouts: vec![5, 3], seed: 4, ..Default::default() },
        ..Default::default()
    }
}

fn assert_batches_identical(a: &Batch, b: &Batch) {
    assert_eq!(a.sub.nodes, b.sub.nodes, "global node ids");
    assert_eq!(a.sub.row, b.sub.row);
    assert_eq!(a.sub.col, b.sub.col);
    assert_eq!(a.sub.edge_ids, b.sub.edge_ids);
    assert_eq!(a.x.data(), b.x.data(), "features");
    assert_eq!(a.labels, b.labels);
    assert_eq!(a.mask, b.mask);
}

/// Run two epochs through `loader` and collect every batch.
fn collect_epochs(loader: &pyg2::dist::DistNeighborLoader) -> Vec<Batch> {
    (0..2u64)
        .flat_map(|e| loader.iter_epoch(e).map(|b| b.unwrap()))
        .collect()
}

#[test]
fn telemetry_leaves_homo_batches_seed_for_seed_identical() {
    let g = sbm::generate(&SbmConfig { num_nodes: 400, seed: 77, ..Default::default() }).unwrap();
    let seeds: Vec<u32> = (0..150).collect();
    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let bundle = write_bundle(tmp("homo_bundle"), &g, &partitioning).unwrap();
    let paged = LruConfig { page_adjacency: true, ..Default::default() };

    // Baseline streams with tracing off: in-memory, mounted resident
    // adjacency, mounted demand-paged adjacency.
    let run_all = || {
        let in_mem = partitioned_loader_with(
            &g,
            &partitioning,
            0,
            seeds.clone(),
            loader_cfg(2),
            DistOptions::default(),
        )
        .unwrap();
        let mounted = mounted_loader(
            &bundle,
            0,
            seeds.clone(),
            loader_cfg(2),
            DistOptions::default(),
            LruConfig::default(),
        )
        .unwrap();
        let paged_loader = mounted_loader(
            &bundle,
            0,
            seeds.clone(),
            loader_cfg(3),
            DistOptions { prefetch: true, ..Default::default() },
            paged,
        )
        .unwrap();
        (collect_epochs(&in_mem), collect_epochs(&mounted), collect_epochs(&paged_loader))
    };

    let _guard = TRACE_LOCK.lock().unwrap();
    obs::set_enabled(false);
    let (base_mem, base_mount, base_paged) = run_all();

    // Same streams with tracing on and the exporter running.
    let dir = tmp("homo_jsonl");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("metrics.jsonl");
    obs::set_enabled(true);
    let ex = obs::Exporter::start(&path, None).unwrap();
    let (traced_mem, traced_mount, traced_paged) = run_all();
    ex.finish().unwrap();
    obs::set_enabled(false);

    for (base, traced) in [
        (&base_mem, &traced_mem),
        (&base_mount, &traced_mount),
        (&base_paged, &traced_paged),
    ] {
        assert_eq!(base.len(), traced.len(), "batch counts");
        for (a, b) in base.iter().zip(traced.iter()) {
            assert_batches_identical(a, b);
        }
    }
    assert!(obs::check_file(&path).unwrap() >= 1, "exporter left a valid JSONL file");
}

#[test]
fn telemetry_leaves_hetero_batches_seed_for_seed_identical() {
    let g = hetero::generate(&HeteroSbmConfig {
        num_users: 300,
        num_items: 200,
        num_tags: 60,
        seed: 77,
        ..Default::default()
    })
    .unwrap();
    let seeds: Vec<u32> = (0..120).collect();
    let tp = TypedPartitioning::ldg_hetero(&g, 3, 1.1).unwrap();
    let bundle = write_bundle_hetero(tmp("hetero_bundle"), &g, &tp).unwrap();
    let cfg = HeteroLoaderConfig {
        batch_size: 16,
        num_workers: 2,
        shuffle: true,
        seed: 13,
        sampler: HeteroSamplerConfig {
            default_fanouts: vec![5, 3],
            seed: 4,
            ..Default::default()
        },
        ..Default::default()
    };

    let run_all = || {
        let in_mem = hetero_partitioned_loader_with(
            &g,
            &tp,
            0,
            "user",
            seeds.clone(),
            cfg.clone(),
            DistOptions::default(),
        )
        .unwrap();
        let mounted = hetero_mounted_loader(
            &bundle,
            0,
            "user",
            seeds.clone(),
            cfg.clone(),
            DistOptions::default(),
            LruConfig::default(),
        )
        .unwrap();
        let collect = |l: &pyg2::dist::HeteroDistNeighborLoader| -> Vec<HeteroBatch> {
            (0..2u64)
                .flat_map(|e| l.iter_epoch(e).map(|b| b.unwrap()))
                .collect()
        };
        (collect(&in_mem), collect(&mounted))
    };

    let _guard = TRACE_LOCK.lock().unwrap();
    obs::set_enabled(false);
    let (base_mem, base_mount) = run_all();
    obs::set_enabled(true);
    let (traced_mem, traced_mount) = run_all();
    obs::set_enabled(false);

    for (base, traced) in [(&base_mem, &traced_mem), (&base_mount, &traced_mount)] {
        assert_eq!(base.len(), traced.len(), "batch counts");
        for (a, b) in base.iter().zip(traced.iter()) {
            assert_eq!(a.sub.nodes, b.sub.nodes, "per-type node ids");
            assert_eq!(
                a.sub.edges.keys().collect::<Vec<_>>(),
                b.sub.edges.keys().collect::<Vec<_>>()
            );
            for (et, ea) in &a.sub.edges {
                let eb = &b.sub.edges[et];
                assert_eq!((&ea.row, &ea.col, &ea.edge_ids), (&eb.row, &eb.col, &eb.edge_ids));
            }
            for (nt, xa) in &a.x {
                assert_eq!(xa.data(), b.x[nt].data(), "{nt} features");
            }
            assert_eq!(a.labels, b.labels);
        }
    }
}

#[test]
fn serving_snapshot_is_one_document_and_predictions_match() {
    let g = sbm::generate(&SbmConfig {
        num_nodes: 600,
        feature_signal: 2.0,
        seed: 9,
        ..Default::default()
    })
    .unwrap();
    let labels = g.y.clone().unwrap();
    let partitioning = ldg_partition(&g.edge_index, 3, 1.1).unwrap();
    let bundle = write_bundle(tmp("serve_bundle"), &g, &partitioning).unwrap();
    // Mounted stores with prefetch so the snapshot carries cache and
    // prefetch metrics alongside router, queue and stage latency.
    let (gs, fs, _) = mounted_stores(
        &bundle,
        0,
        DistOptions { prefetch: true, ..Default::default() },
        LruConfig::default(),
    )
    .unwrap();
    let classes = (*labels.iter().max().unwrap() + 1) as usize;
    let model = Arc::new(
        NodeClassifier::fit(fs.as_ref(), &FeatureKey::default_x(), &labels, classes).unwrap(),
    );

    let spawn = || {
        DistInferenceServer::spawn(
            Arc::clone(&gs),
            Arc::clone(&fs),
            Arc::clone(&model),
            ServeDistConfig { workers: 2, max_batch: 8, prefetch: true, ..Default::default() },
        )
        .unwrap()
    };
    let nodes: Vec<u32> = (0..40u32).collect();

    let _guard = TRACE_LOCK.lock().unwrap();
    obs::set_enabled(false);
    let server = spawn();
    let base: Vec<_> = nodes.iter().map(|&n| server.predict(n).unwrap()).collect();
    drop(server);

    obs::set_enabled(true);
    let server = spawn();
    let traced: Vec<_> = nodes.iter().map(|&n| server.predict(n).unwrap()).collect();
    let snapshot = obs::snapshot_json(0, 0, true).to_string();
    obs::set_enabled(false);
    drop(server);

    for (a, b) in base.iter().zip(traced.iter()) {
        assert_eq!(a.node, b.node);
        assert_eq!(a.class, b.class, "node {}: telemetry changed the prediction", a.node);
        assert_eq!(a.probabilities, b.probabilities, "node {}: probabilities drifted", a.node);
    }

    // The acceptance shape: ONE JSON document carrying router, cache,
    // prefetch, queue, and per-stage latency metrics together.
    let v = pyg2::util::json::parse(&snapshot).unwrap();
    let counters = v.get("counters").unwrap().as_obj().unwrap();
    let gauges = v.get("gauges").unwrap().as_obj().unwrap();
    let hists = v.get("histograms").unwrap().as_obj().unwrap();
    fn has_prefix(m: &BTreeMap<String, Json>, p: &str) -> bool {
        m.keys().any(|k| k.starts_with(p))
    }
    assert!(has_prefix(counters, "dist.router"), "router metrics");
    assert!(has_prefix(counters, "persist.row_cache"), "cache metrics");
    assert!(has_prefix(counters, "dist.prefetch"), "prefetch metrics");
    assert!(has_prefix(gauges, "serve.queue"), "queue depth gauge");
    assert!(has_prefix(hists, "serve.queue"), "queue wait histogram");
    assert!(
        hists.keys().any(|k| k.starts_with("trace.") && k.ends_with("_us")),
        "per-stage latency histograms"
    );
    assert!(has_prefix(counters, "serve."), "serve request counters");
}
