//! Out-of-core equivalence (the persist subsystem's correctness
//! anchor): a distributed pipeline **mounted from a partition bundle on
//! disk** must yield batches *identical* — node ids, edge index,
//! features, labels, padding — to the in-memory distributed pipeline
//! (and hence to the single-store pipeline) under the same loader
//! config, for the homogeneous and the heterogeneous loaders, with and
//! without async routing + halo caching — and all of it again with the
//! adjacency **demand-paged** (`--page-adj`) instead of decoded at
//! mount. On top, the bounded LRU row cache must keep its byte
//! accounting under the configured budget while strictly reducing disk
//! reads on the second epoch; a paged mount must additionally keep the
//! row + adjacency caches jointly under the one shared budget and
//! strictly reduce adjacency disk reads on warm epochs.

use pyg2::coordinator::{
    hetero_mounted_loader, hetero_partitioned_loader_with, mounted_loader,
    multi_rank_epoch, multi_rank_epoch_mounted, partitioned_loader_with, DistOptions,
};
use pyg2::datasets::hetero::{self, HeteroSbmConfig};
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::loader::{Batch, HeteroBatch, HeteroLoaderConfig, LoaderConfig, NeighborLoader};
use pyg2::partition::{ldg_partition, TypedPartitioning};
use pyg2::persist::{write_bundle, write_bundle_hetero, LruConfig};
use pyg2::sampler::{HeteroSamplerConfig, NeighborSamplerConfig};
use pyg2::storage::{InMemoryFeatureStore, InMemoryGraphStore};
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("pyg2_persist_equivalence").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn sbm_graph() -> pyg2::graph::Graph {
    sbm::generate(&SbmConfig { num_nodes: 500, seed: 77, ..Default::default() }).unwrap()
}

fn loader_cfg(workers: usize) -> LoaderConfig {
    LoaderConfig {
        batch_size: 16,
        num_workers: workers,
        shuffle: true,
        seed: 13,
        sampler: NeighborSamplerConfig { fanouts: vec![5, 3], seed: 4, ..Default::default() },
        ..Default::default()
    }
}

fn assert_batches_identical(a: &Batch, b: &Batch) {
    assert_eq!(a.sub.nodes, b.sub.nodes, "global node ids");
    assert_eq!(a.sub.row, b.sub.row, "local edge sources");
    assert_eq!(a.sub.col, b.sub.col, "local edge destinations");
    assert_eq!(a.sub.edge_ids, b.sub.edge_ids, "global edge ids");
    assert_eq!(a.sub.node_offsets, b.sub.node_offsets);
    assert_eq!(a.sub.edge_offsets, b.sub.edge_offsets);
    assert_eq!(a.x.data(), b.x.data(), "features");
    assert_eq!(a.row, b.row, "padded rows");
    assert_eq!(a.col, b.col, "padded cols");
    assert_eq!(a.ew, b.ew, "edge weights");
    assert_eq!(a.mask, b.mask);
    assert_eq!(a.labels, b.labels, "labels");
    assert_eq!(a.seed_mask, b.seed_mask);
    assert_eq!(a.node_pos, b.node_pos);
}

#[test]
fn mounted_pipeline_matches_in_memory_dist_and_single_store() {
    let g = sbm_graph();
    let labels = g.y.clone().unwrap();
    let seeds: Vec<u32> = (0..200).collect();
    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let bundle = write_bundle(tmp("homo_sync"), &g, &partitioning).unwrap();
    assert!(!bundle.is_typed());

    let single = NeighborLoader::new(
        Arc::new(InMemoryGraphStore::from_graph(&g)),
        Arc::new(InMemoryFeatureStore::from_tensor(g.x.clone())),
        seeds.clone(),
        loader_cfg(2),
    )
    .with_labels(labels);
    let in_mem = partitioned_loader_with(
        &g,
        &partitioning,
        0,
        seeds.clone(),
        loader_cfg(3),
        DistOptions::default(),
    )
    .unwrap();
    let mounted = mounted_loader(
        &bundle,
        0,
        seeds,
        loader_cfg(2),
        DistOptions::default(),
        LruConfig::default(),
    )
    .unwrap();

    for epoch in 0..2u64 {
        let a: Vec<Batch> = single.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        let b: Vec<Batch> = in_mem.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        let c: Vec<Batch> = mounted.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        assert_eq!(a.len(), 13); // ceil(200/16)
        assert_eq!(b.len(), c.len());
        for ((x, y), z) in a.iter().zip(&b).zip(&c) {
            x.sub.check_invariants().unwrap();
            assert_batches_identical(x, y);
            assert_batches_identical(y, z);
        }
    }

    // Not vacuous: the mounted epoch crossed partitions and hit disk,
    // with traffic identical to the in-memory distributed pipeline.
    assert_eq!(mounted.router_stats(), in_mem.router_stats());
    assert!(mounted.router_stats().remote_msgs > 0);
    assert!(mounted.features().disk_reads().unwrap() > 0, "rows came from disk");
    let rc = mounted.features().row_cache_stats().unwrap();
    assert!(rc.hits > 0, "repeated rows were served from the LRU: {rc}");
}

#[test]
fn mounted_async_halo_cached_pipeline_matches_single_store_loader() {
    // The full stack out-of-core: bounded LRU under the shards, halo
    // replica filtering the remote path, async router overlapping the
    // RPCs that remain, nonzero simulated latency — still seed-for-seed
    // identical to the single-store loader, from a non-zero rank.
    let g = sbm_graph();
    let labels = g.y.clone().unwrap();
    let seeds: Vec<u32> = (0..200).collect();
    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let bundle = write_bundle(tmp("homo_async"), &g, &partitioning).unwrap();

    let single = NeighborLoader::new(
        Arc::new(InMemoryGraphStore::from_graph(&g)),
        Arc::new(InMemoryFeatureStore::from_tensor(g.x.clone())),
        seeds.clone(),
        loader_cfg(2),
    )
    .with_labels(labels);
    let opts = DistOptions {
        halo_cache: true,
        async_fetch: true,
        async_workers: 2,
        latency: std::time::Duration::from_micros(20),
        ..Default::default()
    };
    let mounted =
        mounted_loader(&bundle, 1, seeds, loader_cfg(3), opts, LruConfig::default()).unwrap();

    for epoch in 0..2u64 {
        let a: Vec<Batch> = single.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        let b: Vec<Batch> = mounted.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_batches_identical(x, y);
        }
    }

    // All three cache/overlap layers actually engaged.
    let halo = mounted.cache_stats().expect("halo cache installed");
    assert!(halo.hits > 0, "halo rows served without an RPC: {halo}");
    assert!(mounted.features().is_async());
    assert!(mounted.router_stats().remote_msgs > 0, "misses still routed");
    assert!(mounted.features().disk_reads().unwrap() > 0);
}

fn hetero_graph() -> pyg2::graph::HeteroGraph {
    hetero::generate(&HeteroSbmConfig {
        num_users: 400,
        num_items: 300,
        num_tags: 80,
        seed: 77,
        ..Default::default()
    })
    .unwrap()
}

fn hetero_cfg(workers: usize) -> HeteroLoaderConfig {
    HeteroLoaderConfig {
        batch_size: 16,
        num_workers: workers,
        shuffle: true,
        seed: 13,
        sampler: HeteroSamplerConfig {
            default_fanouts: vec![5, 3],
            seed: 4,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn assert_hetero_batches_identical(a: &HeteroBatch, b: &HeteroBatch) {
    assert_eq!(a.sub.nodes, b.sub.nodes, "per-type global node ids");
    assert_eq!(a.sub.seed_type, b.sub.seed_type);
    assert_eq!(a.sub.num_seeds, b.sub.num_seeds);
    assert_eq!(a.sub.node_offsets, b.sub.node_offsets);
    assert_eq!(a.sub.batch, b.sub.batch);
    assert_eq!(
        a.sub.edges.keys().collect::<Vec<_>>(),
        b.sub.edges.keys().collect::<Vec<_>>(),
        "edge type sets"
    );
    for (et, ea) in &a.sub.edges {
        let eb = &b.sub.edges[et];
        assert_eq!(ea.row, eb.row, "{} rows", et.key());
        assert_eq!(ea.col, eb.col, "{} cols", et.key());
        assert_eq!(ea.edge_ids, eb.edge_ids, "{} edge ids", et.key());
    }
    for (nt, xa) in &a.x {
        assert_eq!(xa.data(), b.x[nt].data(), "{nt} features");
    }
    assert_eq!(a.labels, b.labels, "labels");
}

#[test]
fn mounted_hetero_pipeline_matches_in_memory_dist_loader() {
    let g = hetero_graph();
    let seeds: Vec<u32> = (0..200).collect();
    let tp = TypedPartitioning::ldg_hetero(&g, 3, 1.1).unwrap();
    let bundle = write_bundle_hetero(tmp("hetero_sync"), &g, &tp).unwrap();
    assert!(bundle.is_typed());
    assert_eq!(bundle.manifest().node_types.len(), 3);
    assert_eq!(bundle.manifest().edge_types.len(), 4);

    let in_mem = hetero_partitioned_loader_with(
        &g,
        &tp,
        0,
        "user",
        seeds.clone(),
        hetero_cfg(2),
        DistOptions::default(),
    )
    .unwrap();
    let mounted = hetero_mounted_loader(
        &bundle,
        0,
        "user",
        seeds,
        hetero_cfg(3),
        DistOptions::default(),
        LruConfig::default(),
    )
    .unwrap();

    for epoch in 0..2u64 {
        let a: Vec<HeteroBatch> = in_mem.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        let b: Vec<HeteroBatch> = mounted.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        assert_eq!(a.len(), b.len());
        assert_eq!(a.len(), 13); // ceil(200/16)
        for (x, y) in a.iter().zip(&b) {
            x.check_invariants().unwrap();
            assert_hetero_batches_identical(x, y);
        }
    }

    assert_eq!(mounted.router_stats(), in_mem.router_stats());
    assert!(mounted.router_stats().remote_msgs > 0, "typed epoch crossed partitions");
    assert!(mounted.features().disk_reads().unwrap() > 0);
    // Unknown seed types are rejected up front.
    assert!(hetero_mounted_loader(
        &bundle,
        0,
        "ghost",
        vec![0],
        hetero_cfg(1),
        DistOptions::default(),
        LruConfig::default(),
    )
    .is_err());
}

#[test]
fn mounted_hetero_async_typed_halo_pipeline_matches_in_memory() {
    let g = hetero_graph();
    let seeds: Vec<u32> = (0..200).collect();
    let tp = TypedPartitioning::ldg_hetero(&g, 4, 1.1).unwrap();
    let bundle = write_bundle_hetero(tmp("hetero_async"), &g, &tp).unwrap();
    let opts = DistOptions {
        halo_cache: true,
        async_fetch: true,
        async_workers: 2,
        latency: std::time::Duration::from_micros(20),
        ..Default::default()
    };

    let in_mem =
        hetero_partitioned_loader_with(&g, &tp, 1, "user", seeds.clone(), hetero_cfg(2), opts)
            .unwrap();
    let mounted =
        hetero_mounted_loader(&bundle, 1, "user", seeds, hetero_cfg(3), opts, LruConfig::default())
            .unwrap();

    for epoch in 0..2u64 {
        let a: Vec<HeteroBatch> = in_mem.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        let b: Vec<HeteroBatch> = mounted.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_hetero_batches_identical(x, y);
        }
    }

    // Per-type halo replicas built *from disk* behave exactly like the
    // in-memory ones: same per-type hit/miss counters.
    assert_eq!(mounted.cache_stats(), in_mem.cache_stats());
    assert!(
        mounted.cache_stats().values().map(|c| c.hits).sum::<u64>() > 0,
        "typed halo rows served locally"
    );
    assert!(mounted.features().is_async());
}

#[test]
fn lru_byte_accounting_stays_under_budget_and_equivalence_survives() {
    let g = sbm_graph();
    let labels = g.y.clone().unwrap();
    let seeds: Vec<u32> = (0..128).collect();
    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let bundle = write_bundle(tmp("homo_budget"), &g, &partitioning).unwrap();

    // A budget of ~40 feature rows for a 500-node graph: constant
    // thrashing, which must change I/O counts only, never batch bytes.
    let row_bytes = (g.x.cols() * 4) as u64;
    let budget = LruConfig { capacity_bytes: 40 * row_bytes, ..Default::default() };
    let mounted =
        mounted_loader(&bundle, 0, seeds.clone(), loader_cfg(2), DistOptions::default(), budget)
            .unwrap();
    let single = NeighborLoader::new(
        Arc::new(InMemoryGraphStore::from_graph(&g)),
        Arc::new(InMemoryFeatureStore::from_tensor(g.x.clone())),
        seeds,
        loader_cfg(2),
    )
    .with_labels(labels);

    let a: Vec<Batch> = single.iter_epoch(0).map(|b| b.unwrap()).collect();
    let b: Vec<Batch> = mounted.iter_epoch(0).map(|b| b.unwrap()).collect();
    for (x, y) in a.iter().zip(&b) {
        assert_batches_identical(x, y);
    }

    let rc = mounted.features().row_cache_stats().unwrap();
    assert!(rc.bytes_cached <= budget.capacity_bytes, "{rc}");
    assert!(rc.peak_bytes <= budget.capacity_bytes, "budget is a hard ceiling: {rc}");
    assert!(rc.evictions > 0, "a 40-row budget over 500 nodes must thrash: {rc}");
    let reads = mounted.features().disk_reads().unwrap();
    assert!(reads > 0);
    assert!(
        reads <= rc.misses,
        "every positioned read serves at least one miss (runs coalesce): \
         {reads} reads vs {} misses",
        rc.misses
    );
}

#[test]
fn second_epoch_strictly_reduces_disk_reads() {
    let g = sbm_graph();
    let seeds: Vec<u32> = (0..200).collect();
    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let bundle = write_bundle(tmp("homo_warm"), &g, &partitioning).unwrap();

    // Roomy budget: the whole working set stays resident.
    let mounted = mounted_loader(
        &bundle,
        0,
        seeds,
        loader_cfg(2),
        DistOptions::default(),
        LruConfig::default(),
    )
    .unwrap();
    let fs = mounted.features();

    for b in mounted.iter_epoch(0) {
        b.unwrap();
    }
    let cold = fs.disk_reads().unwrap();
    assert!(cold > 0, "first epoch pages rows in from disk");

    // A different epoch shuffles differently but revisits mostly the
    // same rows: strictly fewer reads.
    for b in mounted.iter_epoch(1) {
        b.unwrap();
    }
    let warm = fs.disk_reads().unwrap() - cold;
    assert!(
        warm < cold,
        "second epoch must strictly reduce disk reads: {warm} vs {cold}"
    );

    // Replaying the *same* epoch touches exactly the already-resident
    // rows: zero disk reads.
    let before = fs.disk_reads().unwrap();
    for b in mounted.iter_epoch(1) {
        b.unwrap();
    }
    assert_eq!(fs.disk_reads().unwrap(), before, "fully warm epoch reads nothing");
    let rc = fs.row_cache_stats().unwrap();
    assert!(rc.hit_rate() > 0.5, "warm epochs dominate: {rc}");
}

#[test]
fn mounted_multi_rank_matches_in_memory_multi_rank() {
    let g = sbm::generate(&SbmConfig { num_nodes: 400, seed: 3, ..Default::default() }).unwrap();
    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let bundle = write_bundle(tmp("homo_ranks"), &g, &partitioning).unwrap();
    let cfg = LoaderConfig {
        batch_size: 32,
        num_workers: 1,
        shuffle: false,
        sampler: NeighborSamplerConfig { fanouts: vec![4, 2], ..Default::default() },
        ..Default::default()
    };
    let opts = DistOptions { halo_cache: true, async_fetch: true, ..Default::default() };

    let in_mem = multi_rank_epoch(&g, &partitioning, 4, &cfg, opts, 1).unwrap();
    let mounted =
        multi_rank_epoch_mounted(&bundle, 4, &cfg, opts, LruConfig::default(), 1).unwrap();

    assert_eq!(mounted.batches, in_mem.batches);
    assert_eq!(mounted.sampled_nodes, in_mem.sampled_nodes);
    for r in 0..4 {
        for p in 0..4 {
            assert_eq!(
                mounted.matrix.msgs(r, p),
                in_mem.matrix.msgs(r, p),
                "traffic cell ({r}, {p})"
            );
            assert_eq!(mounted.matrix.rows(r, p), in_mem.matrix.rows(r, p));
        }
    }
    for (rank, (a, b)) in mounted.halo.iter().zip(&in_mem.cache).enumerate() {
        assert_eq!(a, b, "rank {rank} halo counters");
    }
    for (rank, (rc, reads)) in mounted.row_cache.iter().zip(&mounted.disk_reads).enumerate() {
        assert!(*reads > 0, "rank {rank} paged rows from disk");
        assert!(*reads <= rc.misses, "rank {rank}: reads never exceed misses");
    }
    assert_eq!(mounted.rank_seconds.len(), 4);
    assert!(mounted.skew().imbalance() >= 1.0);

    // Bad rank counts and typed bundles are rejected.
    assert!(multi_rank_epoch_mounted(&bundle, 0, &cfg, opts, LruConfig::default(), 1).is_err());
    assert!(multi_rank_epoch_mounted(&bundle, 5, &cfg, opts, LruConfig::default(), 1).is_err());
    let hg = hetero_graph();
    let tp = TypedPartitioning::ldg_hetero(&hg, 2, 1.2).unwrap();
    let typed = write_bundle_hetero(tmp("typed_ranks"), &hg, &tp).unwrap();
    assert!(multi_rank_epoch_mounted(&typed, 2, &cfg, opts, LruConfig::default(), 1).is_err());
    assert!(mounted_loader(
        &typed,
        0,
        vec![0],
        cfg,
        DistOptions::default(),
        LruConfig::default()
    )
    .is_err());
}

#[test]
fn mount_rejects_mismatched_bundles() {
    // A bundle mounted with a router that disagrees on partition count
    // or node counts must be rejected, as must unknown ranks.
    let g = sbm::generate(&SbmConfig { num_nodes: 100, seed: 5, ..Default::default() }).unwrap();
    let p = ldg_partition(&g.edge_index, 2, 1.1).unwrap();
    let bundle = write_bundle(tmp("mismatch"), &g, &p).unwrap();
    assert!(pyg2::dist::PartitionedGraphStore::mount(&bundle, 2).is_err(), "rank 2 of 2");
    assert!(pyg2::dist::PartitionedFeatureStore::mount(&bundle, 2, LruConfig::default()).is_err());
    // A router over a different partitioning shape is rejected.
    let other = ldg_partition(&g.edge_index, 3, 1.1).unwrap();
    let router = pyg2::dist::TypedRouter::single(
        pyg2::storage::DEFAULT_GROUP,
        Arc::new(pyg2::dist::PartitionRouter::new(&other, 0).unwrap()),
    );
    assert!(pyg2::dist::PartitionedFeatureStore::mount_with_router(
        &bundle,
        router,
        LruConfig::default()
    )
    .is_err());
}

/// The paged-adjacency mount mode: same default budget, with a quarter
/// carved out for the adjacency block cache.
fn paged_lru() -> LruConfig {
    LruConfig { page_adjacency: true, ..Default::default() }
}

#[test]
fn paged_adjacency_pipeline_matches_in_memory_dist_for_homo_sync_and_async_halo() {
    let g = sbm_graph();
    let labels = g.y.clone().unwrap();
    let seeds: Vec<u32> = (0..200).collect();
    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let bundle = write_bundle(tmp("homo_paged"), &g, &partitioning).unwrap();

    let single = NeighborLoader::new(
        Arc::new(InMemoryGraphStore::from_graph(&g)),
        Arc::new(InMemoryFeatureStore::from_tensor(g.x.clone())),
        seeds.clone(),
        loader_cfg(2),
    )
    .with_labels(labels);

    // Sync from rank 0 and the full async+halo+latency stack from rank
    // 1: both demand-page the topology and must stay seed-for-seed
    // identical to the single-store loader.
    let configs = [
        (0u32, DistOptions::default()),
        (
            1u32,
            DistOptions {
                halo_cache: true,
                async_fetch: true,
                async_workers: 2,
                latency: std::time::Duration::from_micros(20),
                ..Default::default()
            },
        ),
    ];
    for (rank, opts) in configs {
        let mounted =
            mounted_loader(&bundle, rank, seeds.clone(), loader_cfg(3), opts, paged_lru())
                .unwrap();
        assert!(mounted.graph().is_paged());
        for epoch in 0..2u64 {
            let a: Vec<Batch> = single.iter_epoch(epoch).map(|b| b.unwrap()).collect();
            let b: Vec<Batch> = mounted.iter_epoch(epoch).map(|b| b.unwrap()).collect();
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_batches_identical(x, y);
            }
        }
        // Not vacuous: the topology really was paged off disk, and the
        // shared budget was never jointly exceeded.
        assert!(mounted.graph().adj_disk_reads().unwrap() > 0, "adjacency came from disk");
        let rows = mounted.features().row_cache_stats().unwrap();
        let adj = mounted.graph().adj_cache_stats().unwrap();
        let total = paged_lru().capacity_bytes;
        assert!(adj.bytes_cached <= adj.capacity_bytes, "adjacency share ceiling: {adj}");
        assert!(adj.peak_bytes <= adj.capacity_bytes, "adjacency peak ceiling: {adj}");
        assert!(
            rows.bytes_cached + adj.bytes_cached <= total,
            "row + adjacency residency jointly exceed the shared budget: {rows} / {adj}"
        );
        assert!(rows.peak_bytes + adj.peak_bytes <= total);
        assert_eq!(rows.capacity_bytes + adj.capacity_bytes, total, "split tiles the budget");
        if opts.halo_cache {
            let halo = mounted.cache_stats().expect("halo cache installed");
            assert!(halo.hits > 0, "halo rows served without an RPC: {halo}");
            assert!(mounted.features().is_async());
        }
    }
}

#[test]
fn paged_adjacency_hetero_pipeline_matches_in_memory_dist() {
    let g = hetero_graph();
    let seeds: Vec<u32> = (0..200).collect();
    let tp = TypedPartitioning::ldg_hetero(&g, 3, 1.1).unwrap();
    let bundle = write_bundle_hetero(tmp("hetero_paged"), &g, &tp).unwrap();

    let configs = [
        (0u32, DistOptions::default()),
        (
            1u32,
            DistOptions {
                halo_cache: true,
                async_fetch: true,
                async_workers: 2,
                latency: std::time::Duration::from_micros(20),
                ..Default::default()
            },
        ),
    ];
    for (rank, opts) in configs {
        let in_mem = hetero_partitioned_loader_with(
            &g,
            &tp,
            rank,
            "user",
            seeds.clone(),
            hetero_cfg(2),
            opts,
        )
        .unwrap();
        let mounted = hetero_mounted_loader(
            &bundle,
            rank,
            "user",
            seeds.clone(),
            hetero_cfg(3),
            opts,
            paged_lru(),
        )
        .unwrap();
        assert!(mounted.graph().is_paged());
        for epoch in 0..2u64 {
            let a: Vec<HeteroBatch> = in_mem.iter_epoch(epoch).map(|b| b.unwrap()).collect();
            let b: Vec<HeteroBatch> = mounted.iter_epoch(epoch).map(|b| b.unwrap()).collect();
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_hetero_batches_identical(x, y);
            }
        }
        assert_eq!(mounted.router_stats(), in_mem.router_stats());
        assert_eq!(mounted.cache_stats(), in_mem.cache_stats());
        assert!(mounted.graph().adj_disk_reads().unwrap() > 0, "typed adjacency paged from disk");
        let rows = mounted.features().row_cache_stats().unwrap();
        let adj = mounted.graph().adj_cache_stats().unwrap();
        assert!(
            rows.bytes_cached + adj.bytes_cached <= paged_lru().capacity_bytes,
            "shared budget jointly exceeded: {rows} / {adj}"
        );
    }
}

#[test]
fn paged_adjacency_budget_is_a_hard_ceiling_and_warm_epochs_read_less() {
    let g = sbm_graph();
    let seeds: Vec<u32> = (0..200).collect();
    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let bundle = write_bundle(tmp("homo_paged_budget"), &g, &partitioning).unwrap();

    // A deliberately tiny adjacency share: a few hundred bytes over a
    // 500-node topology guarantees constant eviction, which must change
    // I/O counts only — batches stay identical (the labels/nodes path
    // is covered by the equivalence tests; here the ceilings and the
    // warm-read reduction are the assertions).
    let lru = LruConfig {
        capacity_bytes: LruConfig::default().capacity_bytes,
        page_adjacency: true,
        adj_capacity_bytes: 512,
        ..Default::default()
    };
    let mounted =
        mounted_loader(&bundle, 0, seeds, loader_cfg(2), DistOptions::default(), lru).unwrap();
    let gs = mounted.graph();

    for b in mounted.iter_epoch(0) {
        b.unwrap();
    }
    let cold = gs.adj_disk_reads().unwrap();
    assert!(cold > 0, "first epoch pages adjacency in from disk");
    let adj = gs.adj_cache_stats().unwrap();
    assert_eq!(adj.capacity_bytes, 512);
    assert!(adj.bytes_cached <= 512, "{adj}");
    assert!(adj.peak_bytes <= 512, "budget is a hard ceiling: {adj}");
    assert!(adj.evictions > 0, "a 512-byte adjacency budget must thrash: {adj}");

    // A different epoch revisits mostly the same neighborhoods — but
    // under a thrashing budget reads stay high; with a roomy budget the
    // warm epoch must be strictly cheaper.
    let roomy = mounted_loader(
        &bundle,
        0,
        (0..200).collect(),
        loader_cfg(2),
        DistOptions::default(),
        paged_lru(),
    )
    .unwrap();
    let rgs = roomy.graph();
    for b in roomy.iter_epoch(0) {
        b.unwrap();
    }
    let cold = rgs.adj_disk_reads().unwrap();
    assert!(cold > 0);
    for b in roomy.iter_epoch(1) {
        b.unwrap();
    }
    let warm = rgs.adj_disk_reads().unwrap() - cold;
    assert!(
        warm < cold,
        "second epoch must strictly reduce adjacency disk reads: {warm} vs {cold}"
    );
    // Replaying the same epoch touches only resident lists: zero reads.
    let before = rgs.adj_disk_reads().unwrap();
    for b in roomy.iter_epoch(1) {
        b.unwrap();
    }
    assert_eq!(rgs.adj_disk_reads().unwrap(), before, "fully warm epoch reads no adjacency");
    let stats = rgs.adj_cache_stats().unwrap();
    assert!(stats.hit_rate() > 0.5, "warm epochs dominate: {stats}");
}

#[test]
fn paged_multi_rank_matches_in_memory_multi_rank() {
    let g = sbm::generate(&SbmConfig { num_nodes: 400, seed: 3, ..Default::default() }).unwrap();
    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let bundle = write_bundle(tmp("homo_paged_ranks"), &g, &partitioning).unwrap();
    let cfg = LoaderConfig {
        batch_size: 32,
        num_workers: 1,
        shuffle: false,
        sampler: NeighborSamplerConfig { fanouts: vec![4, 2], ..Default::default() },
        ..Default::default()
    };
    let opts = DistOptions { halo_cache: true, async_fetch: true, ..Default::default() };

    let in_mem = multi_rank_epoch(&g, &partitioning, 4, &cfg, opts, 1).unwrap();
    let mounted = multi_rank_epoch_mounted(&bundle, 4, &cfg, opts, paged_lru(), 1).unwrap();

    assert_eq!(mounted.batches, in_mem.batches);
    assert_eq!(mounted.sampled_nodes, in_mem.sampled_nodes);
    for r in 0..4 {
        for p in 0..4 {
            assert_eq!(mounted.matrix.msgs(r, p), in_mem.matrix.msgs(r, p));
            assert_eq!(mounted.matrix.rows(r, p), in_mem.matrix.rows(r, p));
        }
        let adj = mounted.adj_cache[r].expect("paged mount reports the adjacency cache");
        assert!(mounted.adj_disk_reads[r] > 0, "rank {r} paged adjacency from disk");
        let rows = mounted.row_cache[r];
        assert!(
            rows.bytes_cached + adj.bytes_cached <= paged_lru().capacity_bytes,
            "rank {r}: shared budget jointly exceeded"
        );
        let combined = mounted.mount_cache_stats(r);
        assert_eq!(combined.capacity_bytes(), paged_lru().capacity_bytes);
        assert!(combined.bytes_cached() <= combined.capacity_bytes());
    }
}

#[test]
fn adjacency_share_swallowing_the_budget_is_rejected() {
    let g = sbm::generate(&SbmConfig { num_nodes: 80, seed: 5, ..Default::default() }).unwrap();
    let p = ldg_partition(&g.edge_index, 2, 1.1).unwrap();
    let bundle = write_bundle(tmp("bad_split"), &g, &p).unwrap();
    let lru = LruConfig {
        capacity_bytes: 1024,
        page_adjacency: true,
        adj_capacity_bytes: 1024,
        ..Default::default()
    };
    assert!(mounted_loader(&bundle, 0, vec![0], loader_cfg(1), DistOptions::default(), lru)
        .is_err());
}

/// The tiered paged mount: `--page-adj --halo-adj` under the default
/// shared budget, whose halo share is roomy enough to pin every halo
/// in-list of the small test graphs.
fn halo_adj_lru() -> LruConfig {
    LruConfig { page_adjacency: true, halo_adj: true, ..Default::default() }
}

#[test]
fn adjacency_halo_tier_is_seed_for_seed_invisible_homogeneous() {
    // The house rule for the halo tier: batches are byte-identical with
    // the tier on or off — sync and async/halo-cached, paged and
    // resident — because the tier only changes *where* in-list bytes
    // come from, never which bytes.
    let g = sbm_graph();
    let seeds: Vec<u32> = (0..200).collect();
    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let bundle = write_bundle(tmp("homo_halo_adj"), &g, &partitioning).unwrap();

    let legs = [
        DistOptions::default(),
        DistOptions {
            halo_cache: true,
            async_fetch: true,
            async_workers: 2,
            latency: std::time::Duration::from_micros(20),
            ..Default::default()
        },
    ];
    for (i, base) in legs.into_iter().enumerate() {
        let off =
            mounted_loader(&bundle, 1, seeds.clone(), loader_cfg(2), base, paged_lru())
                .unwrap();
        let on = mounted_loader(
            &bundle,
            1,
            seeds.clone(),
            loader_cfg(3),
            DistOptions { halo_adj: true, ..base },
            paged_lru(),
        )
        .unwrap();
        // A resident mount already holds the whole topology locally:
        // --halo-adj must be an accepted no-op there.
        let resident = mounted_loader(
            &bundle,
            1,
            seeds.clone(),
            loader_cfg(2),
            DistOptions { halo_adj: true, ..base },
            LruConfig::default(),
        )
        .unwrap();
        for epoch in 0..2u64 {
            let a: Vec<Batch> = off.iter_epoch(epoch).map(|b| b.unwrap()).collect();
            let b: Vec<Batch> = on.iter_epoch(epoch).map(|b| b.unwrap()).collect();
            let c: Vec<Batch> = resident.iter_epoch(epoch).map(|b| b.unwrap()).collect();
            assert_eq!(a.len(), b.len(), "leg {i}");
            assert_eq!(a.len(), c.len(), "leg {i}");
            for ((x, y), z) in a.iter().zip(&b).zip(&c) {
                assert_batches_identical(x, y);
                assert_batches_identical(x, z);
            }
        }

        // The tier exists exactly where it should and actually served.
        assert!(off.graph().adj_halo_stats().is_none(), "leg {i}: no tier without --halo-adj");
        assert!(
            resident.graph().adj_halo_stats().is_none(),
            "leg {i}: no tier on resident mounts"
        );
        let tier = on.graph().adj_halo_stats().expect("tier built on the paged mount");
        assert!(tier.pinned_entries > 0, "leg {i}: {tier}");
        assert_eq!(tier.spilled_entries, 0, "leg {i}: the default share pins everything");
        assert!(tier.hits > 0, "leg {i}: halo expansions served from the pin: {tier}");

        // Pinned in-lists leave the disk out of halo expansion.
        let (on_reads, off_reads) =
            (on.graph().adj_disk_reads().unwrap(), off.graph().adj_disk_reads().unwrap());
        assert!(
            on_reads < off_reads,
            "leg {i}: the tier must strictly cut adjacency disk reads: {on_reads} vs {off_reads}"
        );
        if !base.halo_cache {
            // ...and the router out of halo traffic accounting. (The
            // async leg bounds its feature-halo replica under the same
            // budget, so its total message count is not comparable.)
            assert!(
                on.router_stats().remote_msgs < off.router_stats().remote_msgs,
                "leg {i}: halo-served expansion must not be billed as remote traffic"
            );
        }
    }
}

#[test]
fn adjacency_halo_tier_is_seed_for_seed_invisible_hetero() {
    let g = hetero_graph();
    let seeds: Vec<u32> = (0..200).collect();
    let tp = TypedPartitioning::ldg_hetero(&g, 3, 1.1).unwrap();
    let bundle = write_bundle_hetero(tmp("hetero_halo_adj"), &g, &tp).unwrap();

    let legs = [
        DistOptions::default(),
        DistOptions {
            halo_cache: true,
            async_fetch: true,
            async_workers: 2,
            latency: std::time::Duration::from_micros(20),
            ..Default::default()
        },
    ];
    for (i, base) in legs.into_iter().enumerate() {
        let off = hetero_mounted_loader(
            &bundle,
            1,
            "user",
            seeds.clone(),
            hetero_cfg(2),
            base,
            paged_lru(),
        )
        .unwrap();
        let on = hetero_mounted_loader(
            &bundle,
            1,
            "user",
            seeds.clone(),
            hetero_cfg(3),
            DistOptions { halo_adj: true, ..base },
            halo_adj_lru(),
        )
        .unwrap();
        let resident = hetero_mounted_loader(
            &bundle,
            1,
            "user",
            seeds.clone(),
            hetero_cfg(2),
            DistOptions { halo_adj: true, ..base },
            LruConfig::default(),
        )
        .unwrap();
        for epoch in 0..2u64 {
            let a: Vec<HeteroBatch> = off.iter_epoch(epoch).map(|b| b.unwrap()).collect();
            let b: Vec<HeteroBatch> = on.iter_epoch(epoch).map(|b| b.unwrap()).collect();
            let c: Vec<HeteroBatch> = resident.iter_epoch(epoch).map(|b| b.unwrap()).collect();
            assert_eq!(a.len(), b.len(), "leg {i}");
            assert_eq!(a.len(), c.len(), "leg {i}");
            for ((x, y), z) in a.iter().zip(&b).zip(&c) {
                assert_hetero_batches_identical(x, y);
                assert_hetero_batches_identical(x, z);
            }
        }

        assert!(off.graph().adj_halo_stats().is_none(), "leg {i}");
        assert!(resident.graph().adj_halo_stats().is_none(), "leg {i}");
        let tier = on.graph().adj_halo_stats().expect("typed tier built");
        assert!(tier.pinned_entries > 0, "leg {i}: {tier}");
        assert!(tier.hits > 0, "leg {i}: typed halo expansions served from the pin: {tier}");
        assert!(
            on.graph().adj_disk_reads().unwrap() < off.graph().adj_disk_reads().unwrap(),
            "leg {i}: typed tier must strictly cut adjacency disk reads"
        );
        if !base.halo_cache {
            assert!(
                on.router_stats().remote_msgs < off.router_stats().remote_msgs,
                "leg {i}: typed halo-served expansion must not be billed as remote traffic"
            );
        }
    }
}

#[test]
fn halo_tier_and_both_lrus_jointly_respect_the_budget_under_pressure() {
    use pyg2::persist::MountCacheStats;

    let g = sbm_graph();
    let seeds: Vec<u32> = (0..200).collect();
    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let bundle = write_bundle(tmp("homo_halo_budget"), &g, &partitioning).unwrap();

    // Shares sized so every tier works for a living: a ~40-row feature
    // share that evicts constantly, a 512-byte adjacency LRU that
    // thrashes, and a 1 KiB halo share that can pin only part of the
    // replica — the rest spills into that thrashing LRU.
    let row_bytes = (g.x.cols() * 4) as u64;
    let lru = LruConfig {
        capacity_bytes: 40 * row_bytes + 512 + 1024,
        page_adjacency: true,
        adj_capacity_bytes: 512,
        halo_adj: true,
        halo_adj_capacity_bytes: 1024,
    };
    let plain_lru = LruConfig {
        capacity_bytes: lru.capacity_bytes,
        page_adjacency: true,
        adj_capacity_bytes: 512,
        ..Default::default()
    };
    let tiered =
        mounted_loader(&bundle, 0, seeds.clone(), loader_cfg(2), DistOptions::default(), lru)
            .unwrap();
    let plain =
        mounted_loader(&bundle, 0, seeds, loader_cfg(2), DistOptions::default(), plain_lru)
            .unwrap();

    // Eviction pressure changes I/O counts only — never batch bytes.
    for epoch in 0..2u64 {
        let a: Vec<Batch> = plain.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        let b: Vec<Batch> = tiered.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_batches_identical(x, y);
        }
    }

    let tier = tiered.graph().adj_halo_stats().expect("tier built");
    assert!(tier.pinned_entries > 0, "{tier}");
    assert!(tier.pinned_bytes <= 1024, "pin share is a hard ceiling: {tier}");
    assert!(tier.spilled_entries > 0, "a 1 KiB share over a 4-part halo must spill: {tier}");
    assert!(tier.total_requests() > 0, "the tier was probed: {tier}");
    let rows = tiered.features().row_cache_stats().unwrap();
    let adj = tiered.graph().adj_cache_stats().unwrap();
    assert!(rows.evictions > 0, "the row share must thrash: {rows}");
    assert!(adj.evictions > 0, "the adjacency share must thrash: {adj}");

    // The three tiers tile the single budget, and joint peak residency
    // never exceeds it.
    assert_eq!(
        rows.capacity_bytes + adj.capacity_bytes + tier.capacity_bytes,
        lru.capacity_bytes,
        "shares tile the budget"
    );
    assert!(
        rows.peak_bytes + adj.peak_bytes + tier.pinned_bytes <= lru.capacity_bytes,
        "joint peak over budget: {rows} / {adj} / {tier}"
    );
    let combined = MountCacheStats { rows, adj: Some(adj), halo: Some(tier) };
    assert_eq!(combined.capacity_bytes(), lru.capacity_bytes);
    assert!(combined.peak_bytes() <= combined.capacity_bytes(), "{combined}");
    assert!(combined.bytes_cached() <= combined.capacity_bytes(), "{combined}");
}
