//! Differential/property suite of the demand-paged adjacency path:
//! for random homogeneous and heterogeneous graphs, neighbor lists
//! served by a paged mount (`PagedAdjacency` behind
//! `PartitionedGraphStore::mount_paged`) must be **byte-identical** —
//! same neighbor order, same edge ids, same timestamps — to the in-RAM
//! CSC/CSR decode of the same bundle, across random query patterns and
//! under tiny cache budgets that force constant eviction. The paged
//! pipeline's seed-for-seed equivalence rests entirely on this
//! slice-level identity.

use pyg2::datasets::hetero::{self, HeteroSbmConfig};
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::dist::PartitionedGraphStore;
use pyg2::graph::EdgeType;
use pyg2::partition::{ldg_partition, TypedPartitioning};
use pyg2::persist::{write_bundle, write_bundle_hetero, AdjBuf, AdjCache};
use pyg2::storage::{default_edge_type, GraphStore, DEFAULT_GROUP};
use pyg2::util::Rng;
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("pyg2_paged_adj_diff").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Query both mounts with the same random node pattern and demand
/// slice equality, in- and out-direction, including per-candidate
/// timestamps wherever the resident mount holds a global time array.
fn assert_identical_lists(
    resident: &PartitionedGraphStore,
    paged: &PartitionedGraphStore,
    et: &EdgeType,
    num_dst: usize,
    num_src: usize,
    queries: usize,
    rng: &mut Rng,
) {
    let res_es = resident.edges_of(et).unwrap();
    let pag_es = paged.edges_of(et).unwrap();
    let time = res_es.resident_edge_time().cloned();
    let mut rb = AdjBuf::default();
    let mut pb = AdjBuf::default();
    for q in 0..queries {
        // Random pattern: mostly random nodes, sprinkled with repeats
        // of the previous query (cache hits) and id-space edges.
        let v = match q % 5 {
            0 => 0,
            1 => (num_dst - 1) as u32,
            _ => rng.index(num_dst) as u32,
        };
        let (rn, re) = res_es.read_in(v, &mut rb).unwrap();
        let (pn, pe, pt) = pag_es.read_in_timed(v, &mut pb, time.is_some()).unwrap();
        assert_eq!(rn, pn, "{}: in-neighbor order of {v}", et.key());
        assert_eq!(re, pe, "{}: in-edge ids of {v}", et.key());
        if let Some(times) = &time {
            let expect: Vec<i64> = re.iter().map(|&e| times[e as usize]).collect();
            assert_eq!(
                pt.expect("paged mount resolves timestamps"),
                &expect[..],
                "{}: per-candidate timestamps of {v}",
                et.key()
            );
        } else {
            assert!(pt.is_none());
        }
        let u = rng.index(num_src) as u32;
        let (rn, re) = res_es.read_out(u, &mut rb).unwrap();
        let (pn, pe) = pag_es.read_out(u, &mut pb).unwrap();
        assert_eq!(rn, pn, "{}: out-neighbor order of {u}", et.key());
        assert_eq!(re, pe, "{}: out-edge ids of {u}", et.key());
    }
}

#[test]
fn random_homo_graphs_serve_identical_lists_under_tiny_budgets() {
    let mut rng = Rng::new(0xADJ0);
    for case in 0..4u64 {
        let n = 60 + (case as usize) * 97;
        let g = sbm::generate(&SbmConfig {
            num_nodes: n,
            seed: 1000 + case,
            ..Default::default()
        })
        .unwrap();
        let parts = 2 + (case as usize % 3);
        let p = ldg_partition(&g.edge_index, parts, 1.1).unwrap();
        let bundle = write_bundle(tmp(&format!("homo_{case}")), &g, &p).unwrap();

        let resident = PartitionedGraphStore::mount(&bundle, 0).unwrap();
        // A budget of a few dozen bytes: nearly every touch evicts, so
        // equality must hold straight off the disk path, not just the
        // cache path.
        for budget in [48u64, 1 << 20] {
            let cache = Arc::new(AdjCache::new(budget));
            let paged =
                PartitionedGraphStore::mount_paged(&bundle, 0, Arc::clone(&cache)).unwrap();
            assert_identical_lists(
                &resident,
                &paged,
                &default_edge_type(),
                n,
                n,
                200,
                &mut rng,
            );
            let stats = cache.stats();
            assert!(stats.bytes_cached <= budget, "budget ceiling: {stats}");
            assert!(stats.peak_bytes <= budget, "peak ceiling: {stats}");
            if budget == 48 {
                assert!(stats.evictions > 0, "tiny budget must evict: {stats}");
            }
        }
    }
}

#[test]
fn random_hetero_graphs_with_timestamps_serve_identical_lists() {
    let mut rng = Rng::new(0xADJ1);
    for case in 0..3u64 {
        let mut g = hetero::generate(&HeteroSbmConfig {
            num_users: 80 + (case as usize) * 40,
            num_items: 60 + (case as usize) * 25,
            num_tags: 20,
            seed: 50 + case,
            ..Default::default()
        })
        .unwrap();
        // Stamp one relation with deterministic pseudo-random
        // timestamps so the paged time path is exercised end to end.
        let timed_et = g.edge_types().next().unwrap().clone();
        let ne = g.edge_store(&timed_et).unwrap().edge_index.num_edges();
        let times: Vec<i64> = (0..ne as i64).map(|e| (e * 37 + case as i64 * 11) % 100 - 50).collect();
        g.set_edge_time(&timed_et, times).unwrap();

        let tp = TypedPartitioning::ldg_hetero(&g, 2 + case as usize, 1.1).unwrap();
        let bundle = write_bundle_hetero(tmp(&format!("hetero_{case}")), &g, &tp).unwrap();

        let resident = PartitionedGraphStore::mount(&bundle, 0).unwrap();
        let cache = Arc::new(AdjCache::new(96));
        let paged = PartitionedGraphStore::mount_paged(&bundle, 0, Arc::clone(&cache)).unwrap();
        for et in resident.edge_types() {
            let n_dst = resident.num_nodes(&et.dst).unwrap();
            let n_src = resident.num_nodes(&et.src).unwrap();
            assert_identical_lists(&resident, &paged, &et, n_dst, n_src, 120, &mut rng);
        }
        let stats = cache.stats();
        assert!(stats.bytes_cached <= 96 && stats.peak_bytes <= 96, "{stats}");
        assert!(stats.evictions > 0, "96-byte budget over 4 relations must evict");

        // The one-pass typed halo sweep agrees with both the per-type
        // computation and the resident decode.
        let paged_halos = paged.halos().unwrap();
        for (nt, halo) in resident.halos().unwrap() {
            assert_eq!(paged_halos[&nt], halo, "{nt} halos");
            assert_eq!(paged.halo_nodes(&nt).unwrap(), halo, "{nt} per-type halo");
        }
    }
}

#[test]
fn paged_structural_summaries_match_resident_decode() {
    let g = sbm::generate(&SbmConfig { num_nodes: 300, seed: 7, ..Default::default() }).unwrap();
    let p = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let bundle = write_bundle(tmp("summaries"), &g, &p).unwrap();
    let resident = PartitionedGraphStore::mount(&bundle, 1).unwrap();
    let paged =
        PartitionedGraphStore::mount_paged(&bundle, 1, Arc::new(AdjCache::new(1 << 20))).unwrap();

    // The streamed (paged) edge walk agrees with the resident COO on
    // everything derived from it: shard sizes, cut edges, halos.
    assert_eq!(paged.shard_edge_counts(), resident.shard_edge_counts());
    assert_eq!(paged.num_cut_edges().unwrap(), resident.num_cut_edges().unwrap());
    assert_eq!(
        paged.halo_nodes(DEFAULT_GROUP).unwrap(),
        resident.halo_nodes(DEFAULT_GROUP).unwrap()
    );
    // Halos remain sorted + deduplicated (the HaloCache contract).
    let halo = paged.halo_nodes(DEFAULT_GROUP).unwrap();
    assert!(halo.windows(2).all(|w| w[0] < w[1]));

    // Merged global views are a clean error, not a silent decode.
    let et = default_edge_type();
    assert!(paged.csc(&et).is_err());
    assert!(paged.csr(&et).is_err());
}

#[test]
fn halo_tier_serves_byte_identical_lists_with_pins_and_spills() {
    // The halo-replication property: every halo in-list a tiered mount
    // serves — pinned in the AdjHaloCache or spilled into the AdjCache
    // LRU — is byte-identical to the resident decode, and pinned
    // entries are served with ZERO disk reads.
    let mut rng = Rng::new(0x4A10);
    let g = sbm::generate(&SbmConfig { num_nodes: 200, seed: 21, ..Default::default() }).unwrap();
    let p = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let bundle = write_bundle(tmp("halo_homo"), &g, &p).unwrap();
    let resident = PartitionedGraphStore::mount(&bundle, 1).unwrap();
    let halos = resident.halo_nodes(DEFAULT_GROUP).unwrap();
    assert!(!halos.is_empty(), "a 4-part SBM cut must produce halo nodes");

    // A 256-byte share forces spills; 1 MiB pins the whole replica.
    for budget in [256u64, 1 << 20] {
        let tiered =
            PartitionedGraphStore::mount_paged(&bundle, 1, Arc::new(AdjCache::new(1 << 20)))
                .unwrap();
        let stats = tiered.build_adj_halo(budget).unwrap().expect("paged mounts build a tier");
        assert_eq!(
            stats.pinned_entries + stats.spilled_entries,
            halos.len() as u64,
            "every halo node is either pinned or spilled"
        );
        assert!(stats.pinned_bytes <= budget, "pin bytes respect the share: {stats}");
        if budget == 256 {
            assert!(stats.spilled_entries > 0, "256 bytes cannot hold the replica: {stats}");
            assert!(stats.pinned_entries > 0, "the hottest entries still pin: {stats}");
        } else {
            assert_eq!(stats.spilled_entries, 0, "1 MiB pins everything: {stats}");
        }

        let et = default_edge_type();
        let res_es = resident.edges_of(&et).unwrap();
        let tier_es = tiered.edges_of(&et).unwrap();
        let mut rb = AdjBuf::default();
        let mut pb = AdjBuf::default();
        let mut pinned_seen = 0u64;
        for &v in &halos {
            let before = tiered.adj_disk_reads().unwrap();
            let (rn, re) = res_es.read_in(v, &mut rb).unwrap();
            let (pn, pe) = tier_es.read_in(v, &mut pb).unwrap();
            assert_eq!(rn, pn, "halo in-neighbors of {v}");
            assert_eq!(re, pe, "halo in-edge ids of {v}");
            if tier_es.halo_served(v) {
                pinned_seen += 1;
                assert_eq!(
                    tiered.adj_disk_reads().unwrap(),
                    before,
                    "pinned halo {v} must be served without a disk read"
                );
            }
        }
        assert_eq!(pinned_seen, stats.pinned_entries, "halo_served ⇔ pinned");
        // Non-halo nodes and out-lists fall through untouched.
        assert_identical_lists(&resident, &tiered, &et, 200, 200, 150, &mut rng);
    }
}

#[test]
fn halo_tier_replicates_typed_timestamps_byte_identically() {
    let mut rng = Rng::new(0x4A11);
    let mut g = hetero::generate(&HeteroSbmConfig {
        num_users: 100,
        num_items: 70,
        num_tags: 25,
        seed: 31,
        ..Default::default()
    })
    .unwrap();
    // Stamp one relation so the tier's timestamp replication (and the
    // spill path's eid-based time resolution) is exercised end to end.
    let timed_et = g.edge_types().next().unwrap().clone();
    let ne = g.edge_store(&timed_et).unwrap().edge_index.num_edges();
    let times: Vec<i64> = (0..ne as i64).map(|e| (e * 53 + 7) % 200 - 100).collect();
    g.set_edge_time(&timed_et, times).unwrap();
    let tp = TypedPartitioning::ldg_hetero(&g, 3, 1.1).unwrap();
    let bundle = write_bundle_hetero(tmp("halo_hetero"), &g, &tp).unwrap();
    let resident = PartitionedGraphStore::mount(&bundle, 1).unwrap();
    let halos = resident.halos().unwrap();

    for budget in [512u64, 1 << 20] {
        let tiered =
            PartitionedGraphStore::mount_paged(&bundle, 1, Arc::new(AdjCache::new(1 << 20)))
                .unwrap();
        let stats = tiered.build_adj_halo(budget).unwrap().expect("typed tier built");
        assert!(stats.pinned_bytes <= budget, "{stats}");
        if budget == 512 {
            assert!(stats.spilled_entries > 0, "{stats}");
        } else {
            assert_eq!(stats.spilled_entries, 0, "{stats}");
        }

        for et in resident.edge_types() {
            let res_es = resident.edges_of(&et).unwrap();
            let tier_es = tiered.edges_of(&et).unwrap();
            let time = res_es.resident_edge_time().cloned();
            let mut rb = AdjBuf::default();
            let mut pb = AdjBuf::default();
            for &v in &halos[&et.dst] {
                let before = tiered.adj_disk_reads().unwrap();
                let (rn, re) = res_es.read_in(v, &mut rb).unwrap();
                let (pn, pe, pt) =
                    tier_es.read_in_timed(v, &mut pb, time.is_some()).unwrap();
                assert_eq!(rn, pn, "{}: halo in-neighbors of {v}", et.key());
                assert_eq!(re, pe, "{}: halo in-edge ids of {v}", et.key());
                if let Some(times) = &time {
                    let expect: Vec<i64> = re.iter().map(|&e| times[e as usize]).collect();
                    assert_eq!(
                        pt.expect("timed relation resolves timestamps"),
                        &expect[..],
                        "{}: replicated timestamps of {v}",
                        et.key()
                    );
                }
                if tier_es.halo_served(v) {
                    assert_eq!(
                        tiered.adj_disk_reads().unwrap(),
                        before,
                        "{}: pinned halo {v} served without disk (timestamps included)",
                        et.key()
                    );
                }
            }
            let n_dst = resident.num_nodes(&et.dst).unwrap();
            let n_src = resident.num_nodes(&et.src).unwrap();
            assert_identical_lists(&resident, &tiered, &et, n_dst, n_src, 80, &mut rng);
        }
    }
}
