//! `FeatureStore::get_into` contract across every backend (§2.3: the
//! training loop must be able to swap backends without semantic drift):
//! rows past `idx.len()` are zeroed (padding for the static-shape
//! buckets), out-of-range indices error without corrupting the output
//! buffer, and column mismatches are rejected.

use pyg2::dist::{PartitionRouter, PartitionedFeatureStore};
use pyg2::graph::{EdgeIndex, Graph};
use pyg2::partition::Partitioning;
use pyg2::persist::{write_bundle, LruConfig};
use pyg2::storage::{
    FeatureKey, FeatureStore, FileFeatureStore, FileFeatureWriter, InMemoryFeatureStore,
};
use pyg2::tensor::Tensor;
use std::sync::Arc;

const N: usize = 10;
const F: usize = 3;

fn source_tensor() -> Tensor {
    let data: Vec<f32> = (0..N * F).map(|i| i as f32).collect();
    Tensor::new(vec![N, F], data).unwrap()
}

fn padding_partitioning() -> Partitioning {
    Partitioning {
        assignment: (0..N).map(|v| (v % 3) as u32).collect(),
        num_parts: 3,
    }
}

/// Per-call unique scratch id: tests run concurrently, so disk-backed
/// fixtures must not share paths.
fn unique_id() -> usize {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// The mounted (out-of-core) store: the same rows written as a
/// partition bundle and demand-paged back through the bounded LRU.
fn mounted_store() -> PartitionedFeatureStore {
    let dir = std::env::temp_dir()
        .join("pyg2_padding_contract_bundle")
        .join(format!("b{}", unique_id()));
    let _ = std::fs::remove_dir_all(&dir);
    let edges = EdgeIndex::new(vec![0, 3, 7], vec![1, 4, 2], N).unwrap();
    let g = Graph::new(edges, source_tensor()).unwrap();
    let bundle = write_bundle(&dir, &g, &padding_partitioning()).unwrap();
    PartitionedFeatureStore::mount(&bundle, 0, LruConfig::default()).unwrap()
}

/// All four backends over identical data: in-memory, file-backed,
/// 3-way partitioned, and 3-way partitioned mounted from disk.
fn backends() -> Vec<(&'static str, Box<dyn FeatureStore>)> {
    let mem = InMemoryFeatureStore::from_tensor(source_tensor());

    let path = std::env::temp_dir().join(format!("pyg2_padding_contract_{}.pygf", unique_id()));
    let mut w = FileFeatureWriter::new(&path);
    w.put(FeatureKey::default_x(), source_tensor());
    w.finish().unwrap();
    let file = FileFeatureStore::open(&path).unwrap();

    let router = Arc::new(PartitionRouter::new(&padding_partitioning(), 0).unwrap());
    let part = PartitionedFeatureStore::partition(
        &InMemoryFeatureStore::from_tensor(source_tensor()),
        router,
    )
    .unwrap();

    vec![
        ("in-memory", Box::new(mem)),
        ("file-backed", Box::new(file)),
        ("partitioned", Box::new(part)),
        ("mounted", Box::new(mounted_store())),
    ]
}

fn row_of(v: usize) -> Vec<f32> {
    (0..F).map(|c| (v * F + c) as f32).collect()
}

#[test]
fn rows_past_idx_len_are_zeroed() {
    for (name, store) in backends() {
        let mut out = Tensor::full(vec![5, F], 9.0);
        store
            .get_into(&FeatureKey::default_x(), &[4, 2], &mut out)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(out.row(0), row_of(4).as_slice(), "{name}: fetched row 0");
        assert_eq!(out.row(1), row_of(2).as_slice(), "{name}: fetched row 1");
        for r in 2..5 {
            assert_eq!(out.row(r), &[0.0; F], "{name}: row {r} must be zero padding");
        }
    }
}

#[test]
fn empty_fetch_zeroes_everything() {
    for (name, store) in backends() {
        let mut out = Tensor::full(vec![3, F], 7.0);
        store
            .get_into(&FeatureKey::default_x(), &[], &mut out)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(
            out.data().iter().all(|&x| x == 0.0),
            "{name}: all rows are padding"
        );
    }
}

#[test]
fn out_of_range_index_errors_and_leaves_buffer_untouched() {
    for (name, store) in backends() {
        // get: plain error.
        assert!(
            store.get(&FeatureKey::default_x(), &[N]).is_err(),
            "{name}: get past the last row must error"
        );
        assert!(
            store.get(&FeatureKey::default_x(), &[0, N + 5]).is_err(),
            "{name}: any out-of-range index must error"
        );
        // get_into: error without partial writes.
        let mut out = Tensor::full(vec![2, F], 5.0);
        assert!(
            store.get_into(&FeatureKey::default_x(), &[0, N], &mut out).is_err(),
            "{name}: get_into past the last row must error"
        );
        assert!(
            out.data().iter().all(|&x| x == 5.0),
            "{name}: failed get_into must not write partial rows"
        );
    }
}

#[test]
fn shape_violations_rejected() {
    for (name, store) in backends() {
        // Wrong column count.
        let mut wrong_cols = Tensor::zeros(vec![4, F + 1]);
        assert!(
            store
                .get_into(&FeatureKey::default_x(), &[0], &mut wrong_cols)
                .is_err(),
            "{name}: column mismatch must error"
        );
        // More indices than output rows.
        let mut small = Tensor::zeros(vec![1, F]);
        assert!(
            store
                .get_into(&FeatureKey::default_x(), &[0, 1], &mut small)
                .is_err(),
            "{name}: capacity overflow must error"
        );
    }
}

#[test]
fn missing_group_errors() {
    for (name, store) in backends() {
        let mut out = Tensor::zeros(vec![1, F]);
        assert!(
            store
                .get_into(&FeatureKey::new("ghost", "x"), &[0], &mut out)
                .is_err(),
            "{name}: unknown group must error"
        );
    }
}
