//! Integration tests across module boundaries: storage ↔ sampler ↔ loader
//! ↔ runtime ↔ coordinator, including failure injection (a feature store
//! that errors mid-epoch) and file-backed storage parity.

use pyg2::coordinator::{default_loader, RunMode, TrainConfig, Trainer};
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::error::{Error, Result};
use pyg2::loader::{LoaderConfig, NeighborLoader};
use pyg2::runtime::Engine;
use pyg2::sampler::NeighborSamplerConfig;
use pyg2::storage::{
    FeatureKey, FeatureStore, FileFeatureStore, FileFeatureWriter, InMemoryFeatureStore,
    InMemoryGraphStore,
};
use pyg2::tensor::Tensor;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[test]
fn file_backed_store_yields_identical_batches() {
    let g = sbm::generate(&SbmConfig { num_nodes: 200, seed: 4, ..Default::default() }).unwrap();
    let gs = Arc::new(InMemoryGraphStore::from_graph(&g));

    // Write features to the binary format, reopen, and compare the loader
    // output with the in-memory store (the remote-backend swap of §2.3:
    // nothing else changes).
    let path = std::env::temp_dir().join("pyg2_e2e_features.pygf");
    let mut w = FileFeatureWriter::new(&path);
    w.put(FeatureKey::default_x(), g.x.clone());
    w.finish().unwrap();

    let cfg = LoaderConfig {
        batch_size: 8,
        num_workers: 2,
        shuffle: false,
        sampler: NeighborSamplerConfig { fanouts: vec![3, 2], ..Default::default() },
        ..Default::default()
    };
    let mem_loader = NeighborLoader::new(
        Arc::clone(&gs),
        Arc::new(InMemoryFeatureStore::from_tensor(g.x.clone())),
        (0..32).collect(),
        cfg.clone(),
    );
    let file_loader = NeighborLoader::new(
        gs,
        Arc::new(FileFeatureStore::open(&path).unwrap()),
        (0..32).collect(),
        cfg,
    );
    for (a, b) in mem_loader.iter_epoch(0).zip(file_loader.iter_epoch(0)) {
        let (a, b) = (a.unwrap(), b.unwrap());
        assert_eq!(a.sub.nodes, b.sub.nodes);
        assert_eq!(a.x.data(), b.x.data(), "file-backed features must match in-memory");
        assert_eq!(a.row, b.row);
        assert_eq!(a.ew, b.ew);
    }
}

/// A feature store that fails after N successful fetches.
struct FlakyStore {
    inner: InMemoryFeatureStore,
    remaining: AtomicUsize,
}

impl FeatureStore for FlakyStore {
    fn get(&self, key: &FeatureKey, idx: &[usize]) -> Result<Tensor> {
        if self.remaining.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
            .is_err()
        {
            return Err(Error::Storage("injected backend failure".into()));
        }
        self.inner.get(key, idx)
    }

    fn feature_dim(&self, key: &FeatureKey) -> Result<usize> {
        self.inner.feature_dim(key)
    }

    fn num_rows(&self, key: &FeatureKey) -> Result<usize> {
        self.inner.num_rows(key)
    }

    fn keys(&self) -> Vec<FeatureKey> {
        self.inner.keys()
    }
}

#[test]
fn loader_surfaces_backend_failures_without_hanging() {
    let g = sbm::generate(&SbmConfig { num_nodes: 150, seed: 5, ..Default::default() }).unwrap();
    let gs = Arc::new(InMemoryGraphStore::from_graph(&g));
    let flaky = Arc::new(FlakyStore {
        inner: InMemoryFeatureStore::from_tensor(g.x.clone()),
        remaining: AtomicUsize::new(3), // batches 0..2 succeed, then errors
    });
    let loader = NeighborLoader::new(
        gs,
        flaky,
        (0..80).collect(),
        LoaderConfig {
            batch_size: 8,
            num_workers: 2,
            shuffle: false,
            sampler: NeighborSamplerConfig { fanouts: vec![3], ..Default::default() },
            ..Default::default()
        },
    );
    let results: Vec<_> = loader.iter_epoch(0).collect();
    assert_eq!(results.len(), 10, "every batch slot must resolve (ok or error)");
    let failures = results.iter().filter(|r| r.is_err()).count();
    assert!(failures >= 1, "the injected failure must surface");
    assert!(
        results.iter().take(3).all(|r| r.is_ok()),
        "in-order delivery keeps early batches intact"
    );
}

#[test]
fn trim_and_full_training_converge_similarly() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::load("artifacts").unwrap();
    let b = engine.manifest().bucket.clone();
    let g = sbm::generate(&SbmConfig {
        num_nodes: 600,
        num_blocks: b.c,
        feature_dim: b.f,
        feature_signal: 1.5,
        seed: 6,
        ..Default::default()
    })
    .unwrap();
    let loader = default_loader(&engine, &g, (0..256).collect(), 1);
    let run = |trim: bool| {
        Trainer::new(
            &engine,
            TrainConfig { trim, epochs: 8, log_every: 0, ..Default::default() },
        )
        .train(&loader)
        .unwrap()
    };
    let full = run(false);
    let trimmed = run(true);
    // Same batches + per-hop degrees unchanged under trimming -> identical
    // learning signal at the seeds: losses must track closely.
    for (a, b) in full.history.iter().zip(&trimmed.history) {
        assert!(
            (a.loss - b.loss).abs() < 0.05 + 0.1 * a.loss,
            "step {}: full {} vs trim {}",
            a.step,
            a.loss,
            b.loss
        );
    }
}

#[test]
fn all_archs_train_one_step_in_both_modes() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::load("artifacts").unwrap();
    let b = engine.manifest().bucket.clone();
    let g = sbm::generate(&SbmConfig {
        num_nodes: 400,
        num_blocks: b.c,
        feature_dim: b.f,
        seed: 7,
        ..Default::default()
    })
    .unwrap();
    let loader = default_loader(&engine, &g, (0..b.s as u32).collect(), 1);
    for arch in ["gcn", "sage", "gin", "gat", "edgecnn"] {
        let mut losses = Vec::new();
        for mode in [RunMode::Compiled, RunMode::Eager] {
            let report = Trainer::new(
                &engine,
                TrainConfig {
                    arch: arch.into(),
                    mode,
                    epochs: 1,
                    log_every: 0,
                    ..Default::default()
                },
            )
            .train(&loader)
            .unwrap();
            assert!(report.final_loss().is_finite(), "{arch} {mode:?}");
            losses.push(report.final_loss());
        }
        assert!(
            (losses[0] - losses[1]).abs() < 1e-3,
            "{arch}: compiled {} vs eager {}",
            losses[0],
            losses[1]
        );
    }
}
