//! Property-based tests for `partition::ldg_partition` (the METIS
//! substitute the distributed stack routes by) and the typed
//! `partition::TypedPartitioning` on top of it, via the in-crate
//! mini-proptest harness: total single assignment (per type), the slack
//! capacity bound, the edge-cut advantage over the random baseline, and
//! typed-halo / untyped-halo agreement on single-type graphs.

use pyg2::datasets::hetero::{self, HeteroSbmConfig};
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::graph::{EdgeIndex, EdgeType, HeteroGraph};
use pyg2::partition::{ldg_capacity, ldg_partition, random_partition, TypedPartitioning};
use pyg2::tensor::Tensor;
use pyg2::util::proptest::{check, Gen};
use pyg2::util::Rng;
use std::collections::BTreeMap;

/// Generator for (num_nodes, num_parts, slack-in-hundredths, graph seed).
struct PartitionCaseGen;

#[derive(Clone, Debug)]
struct PartitionCase {
    num_nodes: usize,
    num_parts: usize,
    /// Slack stored as integer percent (105..=150) so shrinking stays
    /// exact; `slack()` converts.
    slack_pct: usize,
    seed: u64,
}

impl PartitionCase {
    fn slack(&self) -> f64 {
        self.slack_pct as f64 / 100.0
    }

    fn graph(&self) -> EdgeIndex {
        sbm::generate(&SbmConfig {
            num_nodes: self.num_nodes,
            seed: self.seed,
            ..Default::default()
        })
        .unwrap()
        .edge_index
    }
}

impl Gen for PartitionCaseGen {
    type Value = PartitionCase;

    fn generate(&self, rng: &mut Rng) -> PartitionCase {
        PartitionCase {
            num_nodes: 150 + rng.index(450),
            num_parts: 1 + rng.index(8),
            slack_pct: 105 + rng.index(46),
            seed: rng.next_u64() % 1000,
        }
    }

    fn shrink(&self, v: &PartitionCase) -> Vec<PartitionCase> {
        let mut out = Vec::new();
        if v.num_parts > 1 {
            out.push(PartitionCase { num_parts: v.num_parts / 2, ..v.clone() });
            out.push(PartitionCase { num_parts: v.num_parts - 1, ..v.clone() });
        }
        if v.num_nodes > 150 {
            out.push(PartitionCase { num_nodes: 150, ..v.clone() });
        }
        out
    }
}

#[test]
fn every_node_assigned_exactly_once() {
    check(41, &PartitionCaseGen, |case| {
        let edges = case.graph();
        let p = ldg_partition(&edges, case.num_parts, case.slack())
            .map_err(|e| e.to_string())?;
        if p.assignment.len() != case.num_nodes {
            return Err(format!(
                "{} assignments for {} nodes",
                p.assignment.len(),
                case.num_nodes
            ));
        }
        if let Some(&bad) = p.assignment.iter().find(|&&a| a as usize >= case.num_parts) {
            return Err(format!("assignment {bad} out of {} parts", case.num_parts));
        }
        // "Exactly once" means the per-part sizes tile the node set.
        if p.part_sizes().iter().sum::<usize>() != case.num_nodes {
            return Err("part sizes do not sum to num_nodes".into());
        }
        Ok(())
    });
}

#[test]
fn slack_capacity_bound_respected() {
    check(43, &PartitionCaseGen, |case| {
        let edges = case.graph();
        let cap = ldg_capacity(case.num_nodes, case.num_parts, case.slack());
        let p = ldg_partition(&edges, case.num_parts, case.slack())
            .map_err(|e| e.to_string())?;
        for (part, size) in p.part_sizes().into_iter().enumerate() {
            if size > cap {
                return Err(format!(
                    "part {part} holds {size} nodes, capacity {cap} \
                     (n={}, parts={}, slack={})",
                    case.num_nodes,
                    case.num_parts,
                    case.slack()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn typed_ownership_partitions_each_type_exactly_once() {
    check(53, &PartitionCaseGen, |case| {
        let g = hetero::generate(&HeteroSbmConfig {
            num_users: case.num_nodes,
            num_items: case.num_nodes / 2 + 8,
            num_tags: case.num_nodes / 5 + 4,
            seed: case.seed,
            ..Default::default()
        })
        .map_err(|e| e.to_string())?;
        let tp = TypedPartitioning::ldg_hetero(&g, case.num_parts, case.slack())
            .map_err(|e| e.to_string())?;
        if tp.num_parts != case.num_parts {
            return Err(format!("{} parts, wanted {}", tp.num_parts, case.num_parts));
        }
        let mut total = 0usize;
        for nt in ["user", "item", "tag"] {
            let n = g.num_nodes(nt).map_err(|e| e.to_string())?;
            let p = tp.partitioning(nt).map_err(|e| e.to_string())?;
            if p.assignment.len() != n {
                return Err(format!("{nt}: {} assignments for {n} nodes", p.assignment.len()));
            }
            if let Some(&bad) = p.assignment.iter().find(|&&a| a as usize >= case.num_parts) {
                return Err(format!("{nt}: assignment {bad} out of {} parts", case.num_parts));
            }
            // "Exactly once": per-partition node lists tile the type.
            let covered: usize = (0..case.num_parts)
                .map(|part| tp.nodes_of(nt, part as u32).len())
                .sum();
            if covered != n {
                return Err(format!("{nt}: nodes_of covers {covered} of {n} nodes"));
            }
            total += n;
        }
        if tp.total_nodes() != total {
            return Err(format!("total_nodes {} != {total}", tp.total_nodes()));
        }
        Ok(())
    });
}

#[test]
fn typed_halos_match_untyped_halos_on_single_type_graph() {
    check(59, &PartitionCaseGen, |case| {
        let edges = case.graph();
        let p = ldg_partition(&edges, case.num_parts, case.slack())
            .map_err(|e| e.to_string())?;
        // Wrap the same topology as a single-type hetero graph.
        let mut g = HeteroGraph::new();
        g.add_node_type("n", Tensor::zeros(vec![case.num_nodes, 1]))
            .map_err(|e| e.to_string())?;
        g.add_edge_type(
            EdgeType::new("n", "to", "n"),
            EdgeIndex::new(edges.src().to_vec(), edges.dst().to_vec(), case.num_nodes)
                .map_err(|e| e.to_string())?,
        )
        .map_err(|e| e.to_string())?;
        let mut parts = BTreeMap::new();
        parts.insert("n".to_string(), p.clone());
        let tp = TypedPartitioning::from_parts(parts).map_err(|e| e.to_string())?;
        let swept = tp.halos(&g).map_err(|e| e.to_string())?;
        for part in 0..case.num_parts as u32 {
            let untyped = p.halo_nodes(&edges, part);
            let typed = tp.halo_nodes(&g, "n", part).map_err(|e| e.to_string())?;
            if typed != untyped {
                return Err(format!(
                    "partition {part}: typed halo ({} nodes) != untyped halo ({} nodes)",
                    typed.len(),
                    untyped.len()
                ));
            }
            // Sorted + deduplicated (the HaloCache contract) and the
            // one-sweep variant agrees.
            if !typed.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("partition {part}: halo not strictly ascending"));
            }
            if swept["n"][part as usize] != typed {
                return Err(format!("partition {part}: halos() sweep disagrees"));
            }
        }
        Ok(())
    });
}

#[test]
fn edge_cut_beats_random_baseline_on_sbm() {
    check(47, &PartitionCaseGen, |case| {
        let edges = case.graph();
        let ldg = ldg_partition(&edges, case.num_parts, case.slack())
            .map_err(|e| e.to_string())?;
        let rnd = random_partition(case.num_nodes, case.num_parts, case.seed ^ 0x5a5a);
        let (c_ldg, c_rnd) = (ldg.edge_cut(&edges), rnd.edge_cut(&edges));
        // Streaming LDG must never do worse than random placement on a
        // community-structured graph (tiny epsilon for the parts=1 /
        // zero-cut equality case).
        if c_ldg > c_rnd + 1e-9 {
            return Err(format!(
                "LDG cut {c_ldg:.4} worse than random {c_rnd:.4} \
                 (n={}, parts={})",
                case.num_nodes, case.num_parts
            ));
        }
        Ok(())
    });
}
