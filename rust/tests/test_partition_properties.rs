//! Property-based tests for `partition::ldg_partition` (the METIS
//! substitute the distributed stack routes by), via the in-crate
//! mini-proptest harness: total single assignment, the slack capacity
//! bound, and the edge-cut advantage over the random baseline.

use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::graph::EdgeIndex;
use pyg2::partition::{ldg_capacity, ldg_partition, random_partition};
use pyg2::util::proptest::{check, Gen};
use pyg2::util::Rng;

/// Generator for (num_nodes, num_parts, slack-in-hundredths, graph seed).
struct PartitionCaseGen;

#[derive(Clone, Debug)]
struct PartitionCase {
    num_nodes: usize,
    num_parts: usize,
    /// Slack stored as integer percent (105..=150) so shrinking stays
    /// exact; `slack()` converts.
    slack_pct: usize,
    seed: u64,
}

impl PartitionCase {
    fn slack(&self) -> f64 {
        self.slack_pct as f64 / 100.0
    }

    fn graph(&self) -> EdgeIndex {
        sbm::generate(&SbmConfig {
            num_nodes: self.num_nodes,
            seed: self.seed,
            ..Default::default()
        })
        .unwrap()
        .edge_index
    }
}

impl Gen for PartitionCaseGen {
    type Value = PartitionCase;

    fn generate(&self, rng: &mut Rng) -> PartitionCase {
        PartitionCase {
            num_nodes: 150 + rng.index(450),
            num_parts: 1 + rng.index(8),
            slack_pct: 105 + rng.index(46),
            seed: rng.next_u64() % 1000,
        }
    }

    fn shrink(&self, v: &PartitionCase) -> Vec<PartitionCase> {
        let mut out = Vec::new();
        if v.num_parts > 1 {
            out.push(PartitionCase { num_parts: v.num_parts / 2, ..v.clone() });
            out.push(PartitionCase { num_parts: v.num_parts - 1, ..v.clone() });
        }
        if v.num_nodes > 150 {
            out.push(PartitionCase { num_nodes: 150, ..v.clone() });
        }
        out
    }
}

#[test]
fn every_node_assigned_exactly_once() {
    check(41, &PartitionCaseGen, |case| {
        let edges = case.graph();
        let p = ldg_partition(&edges, case.num_parts, case.slack())
            .map_err(|e| e.to_string())?;
        if p.assignment.len() != case.num_nodes {
            return Err(format!(
                "{} assignments for {} nodes",
                p.assignment.len(),
                case.num_nodes
            ));
        }
        if let Some(&bad) = p.assignment.iter().find(|&&a| a as usize >= case.num_parts) {
            return Err(format!("assignment {bad} out of {} parts", case.num_parts));
        }
        // "Exactly once" means the per-part sizes tile the node set.
        if p.part_sizes().iter().sum::<usize>() != case.num_nodes {
            return Err("part sizes do not sum to num_nodes".into());
        }
        Ok(())
    });
}

#[test]
fn slack_capacity_bound_respected() {
    check(43, &PartitionCaseGen, |case| {
        let edges = case.graph();
        let cap = ldg_capacity(case.num_nodes, case.num_parts, case.slack());
        let p = ldg_partition(&edges, case.num_parts, case.slack())
            .map_err(|e| e.to_string())?;
        for (part, size) in p.part_sizes().into_iter().enumerate() {
            if size > cap {
                return Err(format!(
                    "part {part} holds {size} nodes, capacity {cap} \
                     (n={}, parts={}, slack={})",
                    case.num_nodes,
                    case.num_parts,
                    case.slack()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn edge_cut_beats_random_baseline_on_sbm() {
    check(47, &PartitionCaseGen, |case| {
        let edges = case.graph();
        let ldg = ldg_partition(&edges, case.num_parts, case.slack())
            .map_err(|e| e.to_string())?;
        let rnd = random_partition(case.num_nodes, case.num_parts, case.seed ^ 0x5a5a);
        let (c_ldg, c_rnd) = (ldg.edge_cut(&edges), rnd.edge_cut(&edges));
        // Streaming LDG must never do worse than random placement on a
        // community-structured graph (tiny epsilon for the parts=1 /
        // zero-cut equality case).
        if c_ldg > c_rnd + 1e-9 {
            return Err(format!(
                "LDG cut {c_ldg:.4} worse than random {c_rnd:.4} \
                 (n={}, parts={})",
                case.num_nodes, case.num_parts
            ));
        }
        Ok(())
    });
}
