//! Seed-fixed local/distributed equivalence (§2.3's backend-swap
//! property, end to end): a `DistNeighborLoader` over an LDG-partitioned
//! graph must yield batches *identical* — node ids, edge index, fetched
//! features, labels, padding — to the single-store `NeighborLoader`
//! under the same `LoaderConfig`, while actually routing every fetch
//! through the partitioned stores.

use pyg2::coordinator::{partitioned_loader, partitioned_loader_with, DistOptions};
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::loader::{Batch, LoaderConfig, NeighborLoader};
use pyg2::partition::{ldg_partition, random_partition};
use pyg2::sampler::NeighborSamplerConfig;
use pyg2::storage::{InMemoryFeatureStore, InMemoryGraphStore};
use std::sync::Arc;

fn sbm_graph() -> pyg2::graph::Graph {
    sbm::generate(&SbmConfig { num_nodes: 500, seed: 77, ..Default::default() }).unwrap()
}

fn loader_cfg(workers: usize) -> LoaderConfig {
    LoaderConfig {
        batch_size: 16,
        num_workers: workers,
        shuffle: true,
        seed: 13,
        sampler: NeighborSamplerConfig { fanouts: vec![5, 3], seed: 4, ..Default::default() },
        ..Default::default()
    }
}

fn assert_batches_identical(a: &Batch, b: &Batch) {
    // Sampled topology.
    assert_eq!(a.sub.nodes, b.sub.nodes, "global node ids");
    assert_eq!(a.sub.row, b.sub.row, "local edge sources");
    assert_eq!(a.sub.col, b.sub.col, "local edge destinations");
    assert_eq!(a.sub.edge_ids, b.sub.edge_ids, "global edge ids");
    assert_eq!(a.sub.node_offsets, b.sub.node_offsets);
    assert_eq!(a.sub.edge_offsets, b.sub.edge_offsets);
    // Padded batch: features, edge layout, labels, masks.
    assert_eq!(a.x.data(), b.x.data(), "features");
    assert_eq!(a.row, b.row, "padded rows");
    assert_eq!(a.col, b.col, "padded cols");
    assert_eq!(a.ew, b.ew, "edge weights");
    assert_eq!(a.mask, b.mask);
    assert_eq!(a.labels, b.labels, "labels");
    assert_eq!(a.seed_mask, b.seed_mask);
    assert_eq!(a.node_pos, b.node_pos);
}

#[test]
fn dist_loader_over_4_partitions_matches_single_store_loader() {
    let g = sbm_graph();
    let labels = g.y.clone().unwrap();
    let seeds: Vec<u32> = (0..200).collect();

    let single = NeighborLoader::new(
        Arc::new(InMemoryGraphStore::from_graph(&g)),
        Arc::new(InMemoryFeatureStore::from_tensor(g.x.clone())),
        seeds.clone(),
        loader_cfg(2),
    )
    .with_labels(labels);

    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let dist = partitioned_loader(&g, &partitioning, 0, seeds, loader_cfg(3)).unwrap();

    for epoch in 0..2u64 {
        let a: Vec<Batch> = single.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        let b: Vec<Batch> = dist.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        assert_eq!(a.len(), b.len());
        assert_eq!(a.len(), 13); // ceil(200/16)
        for (x, y) in a.iter().zip(&b) {
            x.sub.check_invariants().unwrap();
            x.check_invariants().unwrap();
            assert_batches_identical(x, y);
        }
    }

    // The equivalence is not vacuous: the epoch crossed partitions.
    let stats = dist.router_stats();
    assert!(stats.remote_msgs > 0, "expected cross-partition traffic: {stats}");
}

#[test]
fn equivalence_holds_for_any_partitioning_and_rank() {
    let g = sbm_graph();
    let labels = g.y.clone().unwrap();
    let seeds: Vec<u32> = (0..64).collect();
    let single = NeighborLoader::new(
        Arc::new(InMemoryGraphStore::from_graph(&g)),
        Arc::new(InMemoryFeatureStore::from_tensor(g.x.clone())),
        seeds.clone(),
        loader_cfg(1),
    )
    .with_labels(labels);
    let reference: Vec<Batch> = single.iter_epoch(5).map(|b| b.unwrap()).collect();

    // Batch content must be independent of how the graph is partitioned
    // and which rank we observe from — only the traffic counters differ.
    for (partitioning, rank) in [
        (ldg_partition(&g.edge_index, 2, 1.2).unwrap(), 1),
        (ldg_partition(&g.edge_index, 8, 1.1).unwrap(), 5),
        (random_partition(500, 4, 99), 2),
    ] {
        let dist =
            partitioned_loader(&g, &partitioning, rank, seeds.clone(), loader_cfg(2)).unwrap();
        let got: Vec<Batch> = dist.iter_epoch(5).map(|b| b.unwrap()).collect();
        assert_eq!(got.len(), reference.len());
        for (x, y) in reference.iter().zip(&got) {
            assert_batches_identical(x, y);
        }
    }
}

#[test]
fn async_and_halo_cached_pipeline_matches_single_store_loader() {
    // The full PR 2 stack — halo cache filtering the remote path, async
    // router overlapping the RPCs that remain, nonzero simulated
    // latency — must still be seed-for-seed identical to the
    // single-store loader: neither layer may change batch content, only
    // what the epoch costs.
    let g = sbm_graph();
    let labels = g.y.clone().unwrap();
    let seeds: Vec<u32> = (0..200).collect();

    let single = NeighborLoader::new(
        Arc::new(InMemoryGraphStore::from_graph(&g)),
        Arc::new(InMemoryFeatureStore::from_tensor(g.x.clone())),
        seeds.clone(),
        loader_cfg(2),
    )
    .with_labels(labels);

    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let dist = partitioned_loader_with(
        &g,
        &partitioning,
        1,
        seeds,
        loader_cfg(3),
        DistOptions {
            halo_cache: true,
            async_fetch: true,
            async_workers: 2,
            latency: std::time::Duration::from_micros(20),
            ..Default::default()
        },
    )
    .unwrap();

    for epoch in 0..2u64 {
        let a: Vec<Batch> = single.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        let b: Vec<Batch> = dist.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_batches_identical(x, y);
        }
    }

    // The layers actually engaged: the cache served rows and misses
    // still crossed partitions.
    let cache = dist.cache_stats().expect("halo cache installed");
    assert!(cache.hits > 0, "halo rows were served locally: {cache}");
    assert!(dist.features().is_async());
    assert!(dist.router_stats().remote_msgs > 0, "misses still routed");
}

#[test]
fn halo_cache_accounting_covers_all_remote_requests() {
    // Every remote feature row either hits the replica or is routed:
    // hits + misses must equal the routed remote rows plus the hits, and
    // cached rows must be byte-identical to routed fetches (checked
    // against the single-store loader's features above; here we pin the
    // counter identity on a fresh epoch).
    let g = sbm_graph();
    let seeds: Vec<u32> = (0..128).collect();
    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();

    let uncached = partitioned_loader(&g, &partitioning, 0, seeds.clone(), loader_cfg(2)).unwrap();
    for b in uncached.iter_epoch(0) {
        b.unwrap();
    }
    let base = uncached.router_stats();

    let cached = partitioned_loader_with(
        &g,
        &partitioning,
        0,
        seeds,
        loader_cfg(2),
        DistOptions { halo_cache: true, ..Default::default() },
    )
    .unwrap();
    for b in cached.iter_epoch(0) {
        b.unwrap();
    }
    let stats = cached.router_stats();
    let cache = cached.cache_stats().unwrap();

    // Sampler traffic (edges) is identical in both runs; the feature-row
    // delta between the runs is exactly the hits the cache absorbed.
    assert_eq!(
        stats.remote_rows + cache.hits,
        base.remote_rows,
        "hit/miss accounting must cover every remote row: cached {stats} + {cache} \
         vs uncached {base}"
    );
    assert!(cache.hits > 0);
    assert!(
        stats.remote_rows < base.remote_rows,
        "replicated halo rows must stop crossing partitions"
    );
    assert!(stats.remote_msgs <= base.remote_msgs);
}

#[test]
fn boundary_workload_message_count_strictly_decreases_with_cache() {
    // Rank-local seeds expanded one hop touch only owned nodes and the
    // 1-hop halo — the working set the cache replicates — so the cached
    // pipeline must send strictly fewer (here: zero feature) messages.
    let g = sbm::generate(&SbmConfig {
        num_nodes: 1000,
        num_blocks: 4,
        seed: 9,
        ..Default::default()
    })
    .unwrap();
    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let cfg = LoaderConfig {
        batch_size: 16,
        num_workers: 2,
        shuffle: false,
        sampler: NeighborSamplerConfig { fanouts: vec![8], seed: 4, ..Default::default() },
        ..Default::default()
    };
    let mut seeds = partitioning.nodes_of(0);
    seeds.truncate(128);

    let run = |opts: DistOptions| {
        let dist =
            partitioned_loader_with(&g, &partitioning, 0, seeds.clone(), cfg.clone(), opts)
                .unwrap();
        for b in dist.iter_epoch(0) {
            b.unwrap();
        }
        (dist.router_stats(), dist.cache_stats())
    };

    let (base, _) = run(DistOptions::default());
    let (cached, cache_stats) =
        run(DistOptions { halo_cache: true, async_fetch: true, ..Default::default() });
    assert!(base.remote_msgs > 0, "boundary epoch must fetch halo rows: {base}");
    assert!(
        cached.remote_msgs < base.remote_msgs,
        "async+halo-cache must send strictly fewer messages: {cached} vs {base}"
    );
    assert_eq!(
        cached.remote_msgs, 0,
        "1-hop expansion of owned seeds is exactly the replicated halo"
    );
    let cache_stats = cache_stats.unwrap();
    assert_eq!(cache_stats.misses, 0, "{cache_stats}");
    assert_eq!(cache_stats.hits, base.remote_rows, "every remote row became a hit");
}

#[test]
fn better_partitioning_means_less_traffic() {
    let g = sbm::generate(&SbmConfig {
        num_nodes: 1000,
        num_blocks: 4,
        seed: 9,
        ..Default::default()
    })
    .unwrap();

    // Realistic distributed setup: each rank trains on the seeds it owns,
    // so a low edge cut keeps the sampled neighborhoods (and their
    // feature rows) local. Traffic is then a direct function of
    // partition quality.
    let run = |partitioning: &pyg2::partition::Partitioning| {
        let mut seeds = partitioning.nodes_of(0);
        seeds.truncate(200);
        let dist = partitioned_loader(&g, partitioning, 0, seeds, loader_cfg(2)).unwrap();
        for b in dist.iter_epoch(0) {
            b.unwrap();
        }
        dist.router_stats()
    };

    let ldg = run(&ldg_partition(&g.edge_index, 4, 1.1).unwrap());
    let rnd = run(&random_partition(1000, 4, 3));
    // LDG's lower edge cut must translate into fewer remote payload rows —
    // the whole point of partition-aware loading (§2.3).
    assert!(
        ldg.remote_rows < rnd.remote_rows,
        "LDG traffic {ldg} should undercut random {rnd}"
    );
}
