//! Distributed serving correctness anchors:
//!
//! * **Prediction identity** — a multi-worker [`DistInferenceServer`]
//!   over the partitioned stores (in-memory, mounted, and mounted with
//!   demand-paged adjacency) must serve predictions *identical* to the
//!   single-store [`InferenceServer::spawn_model`] for the same seeds,
//!   model and fanouts. Predictions are a pure function of the node
//!   (`batch_seed = node id` + the DistNeighborSampler ≡ NeighborSampler
//!   invariant), so worker count, batch composition and store backing
//!   must all be invisible.
//! * **Deadline budgets** — an already-expired budget is rejected with
//!   [`Error::Deadline`] at dequeue, over a mounted store too.
//! * **Backend startup failure** — an HLO server whose engine cannot
//!   load (valid manifest, no runtime/artifacts) must close its inbox
//!   and reply errors; callers never hang.

use pyg2::coordinator::{
    mounted_stores, partitioned_stores, DistInferenceServer, DistOptions, InferenceServer,
    Prediction, ServeConfig, ServeDistConfig,
};
use pyg2::error::Error;
use pyg2::nn::{NodeClassifier, ParamStore};
use pyg2::partition::ldg_partition;
use pyg2::persist::{write_bundle, LruConfig};
use pyg2::storage::{FeatureKey, InMemoryFeatureStore, InMemoryGraphStore};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("pyg2_serve_dist").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn fixture() -> (pyg2::graph::Graph, Arc<NodeClassifier>) {
    let g = pyg2::datasets::sbm::generate(&pyg2::datasets::sbm::SbmConfig {
        num_nodes: 500,
        feature_signal: 2.0,
        seed: 77,
        ..Default::default()
    })
    .unwrap();
    let labels = g.y.clone().unwrap();
    let classes = (*labels.iter().max().unwrap() + 1) as usize;
    let fs = InMemoryFeatureStore::from_tensor(g.x.clone());
    let model = Arc::new(
        NodeClassifier::fit(&fs, &FeatureKey::default_x(), &labels, classes).unwrap(),
    );
    (g, model)
}

/// Submit all seeds concurrently (so dynamic batching actually mixes
/// them) and collect the replies in seed order.
fn serve_all(server: &DistInferenceServer, seeds: &[u32]) -> Vec<Prediction> {
    let rxs: Vec<_> = seeds.iter().map(|&n| server.submit(n, None).unwrap()).collect();
    rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect()
}

#[test]
fn multi_worker_mounted_serving_matches_single_store_server() {
    let (g, model) = fixture();
    let seeds: Vec<u32> = (0..80).collect();

    // Reference: the single-store server (one worker, merged stores).
    let single = InferenceServer::spawn_model(
        Arc::new(InMemoryGraphStore::from_graph(&g)),
        Arc::new(InMemoryFeatureStore::from_tensor(g.x.clone())),
        Arc::clone(&model),
        ServeConfig { max_batch: 8, ..Default::default() },
    )
    .unwrap();
    let want: Vec<Prediction> = seeds.iter().map(|&n| single.predict(n).unwrap()).collect();

    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();

    // In-memory partitioned stores, 4 workers.
    let (gs, fs) = partitioned_stores(&g, &partitioning, 0, DistOptions::default()).unwrap();
    let dist = DistInferenceServer::spawn(
        gs,
        fs,
        Arc::clone(&model),
        ServeDistConfig { workers: 4, max_batch: 8, ..Default::default() },
    )
    .unwrap();
    assert_eq!(serve_all(&dist, &seeds), want, "in-memory dist differs");

    // Mounted bundle (resident adjacency), 4 workers.
    let bundle = write_bundle(tmp("identity"), &g, &partitioning).unwrap();
    let (gs, fs, labels) =
        mounted_stores(&bundle, 0, DistOptions::default(), LruConfig::default()).unwrap();
    assert_eq!(labels.as_deref(), g.y.as_deref(), "bundle labels round-trip");
    let mounted = DistInferenceServer::spawn(
        gs,
        fs,
        Arc::clone(&model),
        ServeDistConfig { workers: 4, max_batch: 8, ..Default::default() },
    )
    .unwrap();
    assert_eq!(serve_all(&mounted, &seeds), want, "mounted dist differs");
    // The mounted server actually paged rows through its LRU.
    assert!(mounted.features().row_cache_stats().is_some());

    // Mounted with demand-paged adjacency, 2 workers + async routing.
    let (gs, fs, _) = mounted_stores(
        &bundle,
        0,
        DistOptions { async_fetch: true, ..Default::default() },
        LruConfig { page_adjacency: true, ..Default::default() },
    )
    .unwrap();
    let paged = DistInferenceServer::spawn(
        gs,
        fs,
        Arc::clone(&model),
        ServeDistConfig { workers: 2, max_batch: 8, ..Default::default() },
    )
    .unwrap();
    assert_eq!(serve_all(&paged, &seeds), want, "paged-adjacency dist differs");
    assert!(
        paged.graph().adj_disk_reads().unwrap_or(0) > 0,
        "paged serving must have read adjacency from disk"
    );
}

#[test]
fn expired_budget_is_rejected_over_mounted_store() {
    let (g, model) = fixture();
    let partitioning = ldg_partition(&g.edge_index, 2, 1.1).unwrap();
    let bundle = write_bundle(tmp("deadline"), &g, &partitioning).unwrap();
    let (gs, fs, _) =
        mounted_stores(&bundle, 0, DistOptions::default(), LruConfig::default()).unwrap();
    let server = DistInferenceServer::spawn(
        gs,
        fs,
        model,
        // One worker + a long batching window so the zero budget is
        // guaranteed to be past due by dequeue time.
        ServeDistConfig {
            workers: 1,
            max_batch: 64,
            max_wait: Duration::from_millis(50),
            ..Default::default()
        },
    )
    .unwrap();
    match server.predict_within(7, Some(Duration::ZERO)) {
        Err(Error::Deadline(_)) => {}
        other => panic!("expected Err(Error::Deadline), got {other:?}"),
    }
    assert_eq!(server.stats().deadline_rejected, 1);
    // Budget-free requests still serve afterwards.
    assert!(server.predict(7).is_ok());
}

#[test]
fn engine_load_failure_errors_instead_of_hanging() {
    // A structurally valid manifest pointing at nothing: the spawn-time
    // probe succeeds, then the serve thread's Engine::load fails (no
    // PJRT runtime / no HLO files) — it must close the inbox and reply
    // errors rather than strand callers.
    let dir = tmp("fake_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{
  "programs": {
    "gcn_infer": {"kind": "fused", "file": "gcn_infer.hlo",
                  "params": [], "inputs": [], "outputs": []}
  },
  "buckets": {"default": {"s": 64, "fanouts": [10, 5],
                          "node_cum": [64, 704, 3904],
                          "edge_cum": [0, 640, 3840],
                          "f": 64, "h": 32, "c": 7}}
}"#,
    )
    .unwrap();

    let (g, _) = fixture();
    let manifest = pyg2::runtime::Manifest::load(&dir).unwrap();
    let params = ParamStore::init_for(&manifest, "gcn_infer", 1).unwrap();
    let server = InferenceServer::spawn(
        dir,
        Arc::new(InMemoryGraphStore::from_graph(&g)),
        Arc::new(InMemoryFeatureStore::from_tensor(g.x.clone())),
        params,
        ServeConfig::default(),
    )
    .unwrap();

    // Whether the request was queued before the inbox closed (drained
    // with an error reply) or submitted after (submit itself errors),
    // predict must resolve to Err — promptly, not by hanging.
    let t = Instant::now();
    assert!(server.predict(0).is_err(), "a dead backend must reply errors");
    assert!(server.predict(1).is_err());
    assert!(t.elapsed() < Duration::from_secs(10), "dead-backend predict hung");
}
