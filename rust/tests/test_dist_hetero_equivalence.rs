//! Seed-fixed local/distributed equivalence for the **heterogeneous**
//! pipeline (§2.2 meets §2.3's backend-swap property): a
//! `HeteroDistNeighborLoader` over a typed-partitioned graph must yield
//! batches *identical* — per-node-type node ids, per-edge-type local
//! COO, fetched per-type features, labels — to the in-memory
//! `HeteroNeighborLoader` under the same `HeteroLoaderConfig`, while
//! actually routing every fetch through the `(type, partition)`-keyed
//! stores. The halo-cache and async layers must not change batch
//! content either — only what the epoch costs.

use pyg2::coordinator::{hetero_partitioned_loader, hetero_partitioned_loader_with, DistOptions};
use pyg2::datasets::hetero::{self, HeteroSbmConfig};
use pyg2::dist::{HeteroDistNeighborSampler, PartitionedGraphStore, TypedRouter};
use pyg2::graph::{EdgeType, HeteroGraph};
use pyg2::loader::{HeteroBatch, HeteroLoaderConfig, HeteroNeighborLoader};
use pyg2::partition::{Partitioning, TypedPartitioning};
use pyg2::sampler::{HeteroNeighborSampler, HeteroSamplerConfig};
use pyg2::storage::{InMemoryFeatureStore, InMemoryGraphStore};
use pyg2::util::Rng;
use std::collections::BTreeMap;
use std::sync::Arc;

fn hetero_graph() -> HeteroGraph {
    hetero::generate(&HeteroSbmConfig {
        num_users: 400,
        num_items: 300,
        num_tags: 80,
        seed: 77,
        ..Default::default()
    })
    .unwrap()
}

fn loader_cfg(workers: usize) -> HeteroLoaderConfig {
    HeteroLoaderConfig {
        batch_size: 16,
        num_workers: workers,
        shuffle: true,
        seed: 13,
        sampler: HeteroSamplerConfig {
            default_fanouts: vec![5, 3],
            seed: 4,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn in_memory_loader(
    g: &HeteroGraph,
    seeds: Vec<u32>,
    workers: usize,
) -> HeteroNeighborLoader<InMemoryGraphStore, InMemoryFeatureStore> {
    let labels = g.node_store("user").unwrap().y.clone().unwrap();
    HeteroNeighborLoader::new(
        Arc::new(InMemoryGraphStore::from_hetero(g)),
        Arc::new(InMemoryFeatureStore::from_hetero(g)),
        "user",
        seeds,
        loader_cfg(workers),
    )
    .with_labels(labels)
}

fn random_typed(g: &HeteroGraph, parts: usize, seed: u64) -> TypedPartitioning {
    let mut rng = Rng::new(seed);
    let mut map = BTreeMap::new();
    for nt in g.node_types() {
        let n = g.num_nodes(nt).unwrap();
        map.insert(
            nt.to_string(),
            Partitioning {
                assignment: (0..n).map(|_| rng.index(parts) as u32).collect(),
                num_parts: parts,
            },
        );
    }
    TypedPartitioning::from_parts(map).unwrap()
}

fn assert_batches_identical(a: &HeteroBatch, b: &HeteroBatch) {
    // Sampled typed topology.
    assert_eq!(a.sub.nodes, b.sub.nodes, "per-type global node ids");
    assert_eq!(a.sub.seed_type, b.sub.seed_type);
    assert_eq!(a.sub.num_seeds, b.sub.num_seeds);
    assert_eq!(a.sub.node_offsets, b.sub.node_offsets);
    assert_eq!(a.sub.batch, b.sub.batch);
    assert_eq!(
        a.sub.edges.keys().collect::<Vec<_>>(),
        b.sub.edges.keys().collect::<Vec<_>>(),
        "edge type sets"
    );
    for (et, ea) in &a.sub.edges {
        let eb = &b.sub.edges[et];
        assert_eq!(ea.row, eb.row, "{} rows", et.key());
        assert_eq!(ea.col, eb.col, "{} cols", et.key());
        assert_eq!(ea.edge_ids, eb.edge_ids, "{} edge ids", et.key());
    }
    // Fetched features, per node type.
    assert_eq!(
        a.x.keys().collect::<Vec<_>>(),
        b.x.keys().collect::<Vec<_>>(),
        "feature type sets"
    );
    for (nt, xa) in &a.x {
        assert_eq!(xa.data(), b.x[nt].data(), "{nt} features");
    }
    assert_eq!(a.labels, b.labels, "labels");
}

#[test]
fn hetero_dist_loader_over_4_partitions_matches_in_memory_loader() {
    let g = hetero_graph();
    let seeds: Vec<u32> = (0..200).collect();
    let single = in_memory_loader(&g, seeds.clone(), 2);
    let tp = TypedPartitioning::ldg_hetero(&g, 4, 1.1).unwrap();
    let dist = hetero_partitioned_loader(&g, &tp, 0, "user", seeds, loader_cfg(3)).unwrap();

    for epoch in 0..2u64 {
        let a: Vec<HeteroBatch> = single.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        let b: Vec<HeteroBatch> = dist.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        assert_eq!(a.len(), b.len());
        assert_eq!(a.len(), 13); // ceil(200/16)
        for (x, y) in a.iter().zip(&b) {
            x.check_invariants().unwrap();
            assert_batches_identical(x, y);
        }
    }

    // The equivalence is not vacuous: the epoch crossed partitions, on
    // more than one node type and more than one relation.
    let stats = dist.router_stats();
    assert!(stats.remote_msgs > 0, "expected cross-partition traffic: {stats}");
    let remote_types: usize = dist
        .graph()
        .typed_router()
        .traffic_by_type()
        .values()
        .filter(|t| {
            t.msgs
                .iter()
                .enumerate()
                .any(|(p, &m)| p != t.local_rank as usize && m > 0)
        })
        .count();
    assert!(remote_types >= 2, "typed traffic spans node types");
    let remote_relations = dist
        .edge_traffic()
        .values()
        .filter(|t| t.remote_msgs > 0)
        .count();
    assert!(remote_relations >= 2, "typed traffic spans relations");
}

#[test]
fn hetero_equivalence_holds_for_any_partitioning_and_rank() {
    let g = hetero_graph();
    let seeds: Vec<u32> = (0..64).collect();
    let single = in_memory_loader(&g, seeds.clone(), 1);
    let reference: Vec<HeteroBatch> = single.iter_epoch(5).map(|b| b.unwrap()).collect();

    // Batch content must be independent of how each type is partitioned
    // and which rank we observe from — only the traffic counters differ.
    for (tp, rank) in [
        (TypedPartitioning::ldg_hetero(&g, 2, 1.2).unwrap(), 1u32),
        (TypedPartitioning::ldg_hetero(&g, 8, 1.1).unwrap(), 5),
        (random_typed(&g, 4, 99), 2),
    ] {
        let dist =
            hetero_partitioned_loader(&g, &tp, rank, "user", seeds.clone(), loader_cfg(2))
                .unwrap();
        let got: Vec<HeteroBatch> = dist.iter_epoch(5).map(|b| b.unwrap()).collect();
        assert_eq!(got.len(), reference.len());
        for (x, y) in reference.iter().zip(&got) {
            assert_batches_identical(x, y);
        }
    }
}

#[test]
fn async_and_typed_halo_cached_pipeline_matches_in_memory_loader() {
    // The acceptance stack — per-type halo caches filtering the remote
    // path, async router overlapping the RPCs that remain, nonzero
    // simulated latency — must still be seed-for-seed identical to the
    // in-memory hetero loader.
    let g = hetero_graph();
    let seeds: Vec<u32> = (0..200).collect();
    let single = in_memory_loader(&g, seeds.clone(), 2);
    let tp = TypedPartitioning::ldg_hetero(&g, 4, 1.1).unwrap();
    let dist = hetero_partitioned_loader_with(
        &g,
        &tp,
        1,
        "user",
        seeds,
        loader_cfg(3),
        DistOptions {
            halo_cache: true,
            async_fetch: true,
            async_workers: 2,
            latency: std::time::Duration::from_micros(20),
            ..Default::default()
        },
    )
    .unwrap();

    for epoch in 0..2u64 {
        let a: Vec<HeteroBatch> = single.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        let b: Vec<HeteroBatch> = dist.iter_epoch(epoch).map(|b| b.unwrap()).collect();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_batches_identical(x, y);
        }
    }

    // The layers actually engaged: per-type caches served rows and
    // misses still crossed partitions.
    let cache = dist.cache_stats();
    assert_eq!(cache.len(), 3, "one cache per node type");
    assert!(
        cache.values().map(|c| c.hits).sum::<u64>() > 0,
        "typed halo rows were served locally"
    );
    assert!(dist.features().is_async());
    assert!(dist.router_stats().remote_msgs > 0, "misses still routed");
}

#[test]
fn typed_halo_cache_accounting_covers_all_remote_requests() {
    let g = hetero_graph();
    let seeds: Vec<u32> = (0..128).collect();
    let tp = TypedPartitioning::ldg_hetero(&g, 4, 1.1).unwrap();

    let uncached =
        hetero_partitioned_loader(&g, &tp, 0, "user", seeds.clone(), loader_cfg(2)).unwrap();
    for b in uncached.iter_epoch(0) {
        b.unwrap();
    }
    let base = uncached.router_stats();

    let cached = hetero_partitioned_loader_with(
        &g,
        &tp,
        0,
        "user",
        seeds,
        loader_cfg(2),
        DistOptions { halo_cache: true, ..Default::default() },
    )
    .unwrap();
    for b in cached.iter_epoch(0) {
        b.unwrap();
    }
    let stats = cached.router_stats();
    let hits: u64 = cached.cache_stats().values().map(|c| c.hits).sum();

    // Sampler traffic (edges) is identical in both runs; the feature-row
    // delta between the runs is exactly the hits the typed caches
    // absorbed.
    assert_eq!(
        stats.remote_rows + hits,
        base.remote_rows,
        "per-type hit/miss accounting must cover every remote row"
    );
    assert!(hits > 0);
    assert!(stats.remote_rows < base.remote_rows);
    assert!(stats.remote_msgs <= base.remote_msgs);
}

#[test]
fn boundary_workload_message_count_strictly_decreases_with_typed_cache() {
    // Rank-local user seeds expanded one hop touch only owned users and
    // the typed 1-hop halos — the working set the per-type caches
    // replicate — so the cached pipeline must send strictly fewer (here:
    // zero feature) messages.
    let g = hetero_graph();
    let tp = TypedPartitioning::ldg_hetero(&g, 4, 1.1).unwrap();
    let cfg = HeteroLoaderConfig {
        batch_size: 16,
        num_workers: 2,
        shuffle: false,
        sampler: HeteroSamplerConfig {
            default_fanouts: vec![8],
            seed: 4,
            ..Default::default()
        },
        ..Default::default()
    };
    let mut seeds = tp.nodes_of("user", 0);
    seeds.truncate(96);

    let run = |opts: DistOptions| {
        let dist =
            hetero_partitioned_loader_with(&g, &tp, 0, "user", seeds.clone(), cfg.clone(), opts)
                .unwrap();
        for b in dist.iter_epoch(0) {
            b.unwrap();
        }
        (dist.router_stats(), dist.cache_stats())
    };

    let (base, _) = run(DistOptions::default());
    let (cached, cache_stats) =
        run(DistOptions { halo_cache: true, async_fetch: true, ..Default::default() });
    assert!(base.remote_msgs > 0, "boundary epoch must fetch halo rows: {base}");
    assert!(
        cached.remote_msgs < base.remote_msgs,
        "async+typed-halo-cache must send strictly fewer messages: {cached} vs {base}"
    );
    assert_eq!(
        cached.remote_msgs, 0,
        "1-hop expansion of owned user seeds is exactly the typed halos"
    );
    let misses: u64 = cache_stats.values().map(|c| c.misses).sum();
    assert_eq!(misses, 0, "{cache_stats:?}");
    let hits: u64 = cache_stats.values().map(|c| c.hits).sum();
    assert_eq!(hits, base.remote_rows, "every remote row became a typed hit");
}

#[test]
fn dist_sampler_matches_in_memory_sampler_on_sbm_scale() {
    // Sampler-level equivalence at scale, across configs the unit tests
    // don't reach (per-edge-type fanouts + disjoint trees on the typed
    // SBM), from a non-zero rank.
    let g = hetero_graph();
    let mem = Arc::new(InMemoryGraphStore::from_hetero(&g));
    let tp = TypedPartitioning::ldg_hetero(&g, 4, 1.1).unwrap();
    let router = TypedRouter::new(&tp, 3).unwrap();
    let part = Arc::new(PartitionedGraphStore::from_hetero(&g, router).unwrap());

    let mut per_type = BTreeMap::new();
    per_type.insert(EdgeType::new("tag", "on", "item"), vec![0usize, 4]);
    let configs = [
        HeteroSamplerConfig { default_fanouts: vec![10, 5], ..Default::default() },
        HeteroSamplerConfig {
            fanouts_per_edge_type: per_type,
            default_fanouts: vec![4, 4, 2],
            disjoint: true,
            seed: 11,
        },
    ];
    for cfg in configs {
        let single = HeteroNeighborSampler::new(Arc::clone(&mem), cfg.clone());
        let dist = HeteroDistNeighborSampler::new(Arc::clone(&part), cfg.clone());
        for batch_seed in [0u64, 7, 1_000_003] {
            let seeds = [1u32, 42, 399, 17];
            let a = single.sample("user", &seeds, None, batch_seed).unwrap();
            let b = dist.sample("user", &seeds, None, batch_seed).unwrap();
            a.check_invariants().unwrap();
            b.check_invariants().unwrap();
            assert_eq!(a.nodes, b.nodes);
            assert_eq!(a.node_offsets, b.node_offsets);
            assert_eq!(a.batch, b.batch);
            for (et, ea) in &a.edges {
                let eb = &b.edges[et];
                assert_eq!(ea.row, eb.row, "{}", et.key());
                assert_eq!(ea.col, eb.col, "{}", et.key());
                assert_eq!(ea.edge_ids, eb.edge_ids, "{}", et.key());
            }
        }
    }
}
