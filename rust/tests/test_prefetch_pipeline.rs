//! Pipeline-prefetch equivalence and I/O-ledger suite.
//!
//! Pins the tentpole contracts of the prefetch + batched-I/O work:
//!
//! 1. **Prefetch is invisible.** `--prefetch` only warms caches — it
//!    touches no RNG and no router — so every mounted pipeline leg
//!    (homogeneous + hetero, sync + async/halo-cached) must yield
//!    byte-identical batch streams with it on and off.
//! 2. **Indptr residency bounds reads.** With the tiny indptr arrays
//!    resident, an adjacency-cache miss costs at most ONE positioned
//!    read (the neighbor-list payload), never an extra indptr read:
//!    `adj_disk_reads <= adj misses` on every cold epoch.
//! 3. **Backends agree.** `--io-backend pread` and `mmap` serve the
//!    same bytes, hence the same batches.

use pyg2::coordinator::{
    hetero_mounted_loader, mounted_loader, mounted_stores, multi_rank_epoch_mounted,
    DistInferenceServer, DistOptions, ServeDistConfig,
};
use pyg2::datasets::hetero::{self, HeteroSbmConfig};
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::loader::{HeteroLoaderConfig, LoaderConfig};
use pyg2::nn::NodeClassifier;
use pyg2::partition::{ldg_partition, TypedPartitioning};
use pyg2::persist::{write_bundle, write_bundle_hetero, Bundle, IoBackend, LruConfig};
use pyg2::sampler::{HeteroSamplerConfig, NeighborSamplerConfig};
use pyg2::storage::FeatureKey;
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("pyg2_prefetch_pipeline").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A homogeneous 2-partition bundle on disk.
fn homo_bundle(name: &str) -> Bundle {
    let g = sbm::generate(&SbmConfig { num_nodes: 240, seed: 5, ..Default::default() }).unwrap();
    let p = ldg_partition(&g.edge_index, 2, 1.1).unwrap();
    write_bundle(tmp(name), &g, &p).unwrap()
}

/// A typed user/item/tag 2-partition bundle on disk.
fn hetero_bundle(name: &str) -> Bundle {
    let g = hetero::generate(&HeteroSbmConfig {
        num_users: 80,
        num_items: 60,
        num_tags: 20,
        seed: 7,
        ..Default::default()
    })
    .unwrap();
    let tp = TypedPartitioning::ldg_hetero(&g, 2, 1.1).unwrap();
    write_bundle_hetero(tmp(name), &g, &tp).unwrap()
}

fn paged_lru() -> LruConfig {
    LruConfig {
        capacity_bytes: 1 << 20,
        page_adjacency: true,
        adj_capacity_bytes: 0,
        ..Default::default()
    }
}

fn loader_cfg() -> LoaderConfig {
    LoaderConfig {
        batch_size: 32,
        num_workers: 2,
        sampler: NeighborSamplerConfig { fanouts: vec![4, 2], ..Default::default() },
        ..Default::default()
    }
}

/// The full observable content of one homogeneous batch.
type HomoKey = (Vec<u32>, Vec<f32>, Vec<i32>);

fn homo_epochs(bundle: &Bundle, opts: DistOptions, epochs: u64) -> (Vec<HomoKey>, Option<pyg2::dist::PrefetchStats>) {
    let loader =
        mounted_loader(bundle, 0, (0..240).collect(), loader_cfg(), opts, paged_lru()).unwrap();
    let mut out = Vec::new();
    for e in 0..epochs {
        for b in loader.iter_epoch(e) {
            let b = b.unwrap();
            out.push((b.sub.nodes.clone(), b.x.data().to_vec(), b.labels.clone()));
        }
    }
    (out, loader.prefetch_stats())
}

#[test]
fn prefetch_on_off_batch_streams_identical_homogeneous() {
    let bundle = homo_bundle("homo_eq");
    let legs = [
        DistOptions::default(),
        DistOptions {
            halo_cache: true,
            async_fetch: true,
            async_workers: 2,
            ..Default::default()
        },
    ];
    for (i, base) in legs.into_iter().enumerate() {
        let (off, off_stats) = homo_epochs(&bundle, base, 2);
        let (on, on_stats) =
            homo_epochs(&bundle, DistOptions { prefetch: true, ..base }, 2);
        assert_eq!(off, on, "leg {i}: prefetch changed batch content");
        assert!(off_stats.is_none(), "leg {i}: no prefetcher without --prefetch");
        let on_stats = on_stats.expect("prefetcher installed");
        // One warm job per batch per epoch: ceil(240/32) = 8, x2 epochs.
        assert_eq!(on_stats.scheduled, 16, "leg {i}");
        assert_eq!(on_stats.failed, 0, "leg {i}: warming must never fail");
    }
}

/// The full observable content of one hetero batch.
type HeteroKey = (
    std::collections::BTreeMap<String, Vec<u32>>,
    Vec<(String, Vec<u32>, Vec<u32>, Vec<u32>)>,
    Vec<(String, Vec<f32>)>,
);

fn hetero_epochs(bundle: &Bundle, opts: DistOptions, epochs: u64) -> (Vec<HeteroKey>, Option<pyg2::dist::PrefetchStats>) {
    let cfg = HeteroLoaderConfig {
        batch_size: 16,
        num_workers: 2,
        sampler: HeteroSamplerConfig { default_fanouts: vec![3, 2], ..Default::default() },
        ..Default::default()
    };
    let loader =
        hetero_mounted_loader(bundle, 0, "user", (0..80).collect(), cfg, opts, paged_lru())
            .unwrap();
    let mut out = Vec::new();
    for e in 0..epochs {
        for b in loader.iter_epoch(e) {
            let b = b.unwrap();
            let edges = b
                .sub
                .edges
                .iter()
                .map(|(et, e)| (et.key(), e.row.clone(), e.col.clone(), e.edge_ids.clone()))
                .collect();
            let x = b.x.iter().map(|(nt, t)| (nt.clone(), t.data().to_vec())).collect();
            out.push((b.sub.nodes.clone(), edges, x));
        }
    }
    (out, loader.prefetch_stats())
}

#[test]
fn prefetch_on_off_batch_streams_identical_hetero() {
    let bundle = hetero_bundle("hetero_eq");
    let legs = [
        DistOptions::default(),
        DistOptions {
            halo_cache: true,
            async_fetch: true,
            async_workers: 2,
            ..Default::default()
        },
    ];
    for (i, base) in legs.into_iter().enumerate() {
        let (off, off_stats) = hetero_epochs(&bundle, base, 2);
        let (on, on_stats) =
            hetero_epochs(&bundle, DistOptions { prefetch: true, ..base }, 2);
        assert_eq!(off, on, "leg {i}: prefetch changed hetero batch content");
        assert!(off_stats.is_none(), "leg {i}");
        let on_stats = on_stats.expect("prefetcher installed");
        assert_eq!(on_stats.scheduled, 10, "leg {i}: ceil(80/16) x 2 epochs");
        assert_eq!(on_stats.failed, 0, "leg {i}");
    }
}

#[test]
fn indptr_residency_bounds_adjacency_reads_by_misses() {
    let bundle = homo_bundle("residency");
    let loader = mounted_loader(
        &bundle,
        0,
        (0..240).collect(),
        loader_cfg(),
        DistOptions::default(),
        paged_lru(),
    )
    .unwrap();
    let n: usize = loader.iter_epoch(0).map(|b| b.unwrap().num_real_nodes()).sum();
    assert!(n > 0);
    let gs = loader.graph();
    let stats = gs.adj_cache_stats().expect("paged adjacency");
    let reads = gs.adj_disk_reads().expect("paged adjacency");
    assert!(stats.misses > 0, "cold epoch must miss");
    // Resident indptr: a miss costs at most one coalesced positioned
    // read — never a second read to locate the list.
    assert!(
        reads <= stats.misses,
        "{reads} disk reads for {} misses: indptr residency lost",
        stats.misses
    );
}

#[test]
fn pread_and_mmap_backends_serve_identical_batches() {
    let bundle = homo_bundle("backends");
    let (pread, _) = homo_epochs(&bundle, DistOptions::default(), 1);
    let (mmap, _) = homo_epochs(
        &bundle,
        DistOptions { io_backend: IoBackend::Mmap, ..Default::default() },
        1,
    );
    assert_eq!(pread, mmap, "io backends must be byte-identical");
}

#[test]
fn multi_rank_mounted_reports_prefetch_per_rank() {
    let bundle = homo_bundle("multi_rank");
    let run = |prefetch: bool| {
        multi_rank_epoch_mounted(
            &bundle,
            2,
            &loader_cfg(),
            DistOptions { prefetch, ..Default::default() },
            paged_lru(),
            1,
        )
        .unwrap()
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.batches, on.batches);
    assert_eq!(off.sampled_nodes, on.sampled_nodes, "warming changed sampling");
    assert!(off.prefetch.iter().all(|p| p.is_none()));
    for (r, p) in on.prefetch.iter().enumerate() {
        let p = p.as_ref().expect("per-rank prefetch stats");
        assert!(p.scheduled > 0, "rank {r} scheduled nothing");
        assert_eq!(p.failed, 0, "rank {r}");
    }
}

#[test]
fn serve_dist_prefetch_leaves_predictions_unchanged() {
    let bundle = homo_bundle("serve");
    let predict_all = |prefetch: bool| {
        let opts = DistOptions { prefetch, ..Default::default() };
        let (gs, fs, labels) = mounted_stores(&bundle, 0, opts, paged_lru()).unwrap();
        let labels = labels.expect("SBM bundles carry labels");
        let classes = (*labels.iter().max().unwrap() + 1) as usize;
        let model = Arc::new(
            NodeClassifier::fit(fs.as_ref(), &FeatureKey::default_x(), &labels, classes)
                .unwrap(),
        );
        let server = DistInferenceServer::spawn(
            gs,
            fs,
            model,
            ServeDistConfig { workers: 2, prefetch, ..Default::default() },
        )
        .unwrap();
        let preds: Vec<usize> =
            (0..40u32).map(|n| server.predict(n).unwrap().class).collect();
        let stats = server.prefetch_stats();
        (preds, stats)
    };
    let (off, off_stats) = predict_all(false);
    let (on, on_stats) = predict_all(true);
    assert_eq!(off, on, "prefetch changed served predictions");
    assert!(off_stats.is_none());
    let on_stats = on_stats.expect("server-side prefetcher installed");
    assert!(on_stats.scheduled > 0);
    assert_eq!(on_stats.failed, 0);
}
