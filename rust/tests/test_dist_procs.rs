//! Acceptance anchors for true multi-process ranks (`pyg2 dist --procs`):
//!
//! 1. the real run — N worker processes over one shared bundle, feature
//!    rows fetched peer-to-peer over unix sockets — produces the SAME
//!    per-rank batch digest streams and the SAME aggregated traffic
//!    matrix as the sequential `multi_rank_epoch_mounted` simulation,
//!    seed for seed;
//! 2. a worker killed mid-epoch surfaces as a typed `Error::Worker` at
//!    the parent within the deadline — no hang, no panic;
//! 3. the CLI fails cleanly (exit 1, `error:` on stderr, no panic) on
//!    an unwritable `--metrics-out` and on a telemetry file truncated
//!    mid-record.

use pyg2::coordinator::{multi_rank_epoch_mounted, DistOptions, DistProcsConfig};
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::error::Error;
use pyg2::loader::LoaderConfig;
use pyg2::partition::ldg_partition;
use pyg2::persist::{write_bundle, Bundle, LruConfig};
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("pyg2_test_procs").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn write_fixture_bundle(name: &str, parts: usize) -> Bundle {
    let g = sbm::generate(&SbmConfig { num_nodes: 400, seed: 21, ..Default::default() }).unwrap();
    let p = ldg_partition(&g.edge_index, parts, 1.1).unwrap();
    write_bundle(tmp(name), &g, &p).unwrap()
}

fn procs_config(bundle: &Bundle, procs: usize, forward: &[&str]) -> DistProcsConfig {
    DistProcsConfig {
        bin: PathBuf::from(env!("CARGO_BIN_EXE_pyg2")),
        mount: bundle.dir().to_path_buf(),
        procs,
        forward: forward.iter().map(|s| s.to_string()).collect(),
        deadline: Duration::from_secs(60),
        metrics_out: None,
    }
}

#[test]
fn multi_process_run_matches_simulation_seed_for_seed() {
    let bundle = write_fixture_bundle("pin_bundle", 4);
    let procs = 2;

    let sim = multi_rank_epoch_mounted(
        &bundle,
        procs,
        &LoaderConfig { batch_size: 16, num_workers: 2, ..Default::default() },
        DistOptions::default(),
        LruConfig::default(),
        1,
    )
    .unwrap();

    let real = pyg2::coordinator::run_parent(&procs_config(
        &bundle,
        procs,
        &["--batch=16", "--workers=2", "--epochs=1"],
    ))
    .unwrap();

    // Batch streams: every rank produced the same batches in the same
    // order, down to feature bytes and edge weights.
    assert_eq!(real.digests.len(), procs);
    for (rank, (r, s)) in real.digests.iter().zip(&sim.digests).enumerate() {
        assert!(!r.is_empty(), "rank {rank} produced no batches");
        assert_eq!(r, s, "rank {rank}: digest stream diverged from the simulation");
    }
    assert_eq!(real.batches, sim.batches);
    assert_eq!(real.sampled_nodes, sim.sampled_nodes);

    // Traffic: the socket transport sits behind the requester-side
    // accounting, so the aggregated rank x partition matrix is
    // identical to the simulated one.
    assert_eq!(
        format!("{}", real.matrix),
        format!("{}", sim.matrix),
        "traffic matrix diverged from the simulation"
    );

    // The run actually overlapped: every rank reported wall-clock and
    // the parent measured a positive window containing all of them.
    assert_eq!(real.rank_seconds.len(), procs);
    assert!(real.wall_seconds > 0.0);
    assert!(real.overlap() > 0.0);
}

#[test]
fn killed_worker_is_a_typed_error_within_the_deadline() {
    let bundle = write_fixture_bundle("kill_bundle", 4);
    let mut cfg = procs_config(
        &bundle,
        2,
        // Rank 0 and rank 1 both exit abruptly after one batch; the
        // parent must notice through child liveness, not a timeout.
        &["--batch=16", "--workers=2", "--fail-after-batches=1"],
    );
    cfg.deadline = Duration::from_secs(45);
    let t0 = Instant::now();
    match pyg2::coordinator::run_parent(&cfg) {
        Err(Error::Worker(m)) => {
            assert!(
                m.contains("exited prematurely") || m.contains("worker"),
                "unexpected worker error: {m}"
            );
        }
        Ok(_) => panic!("a killed worker must fail the run"),
        Err(other) => panic!("expected Error::Worker, got {other}"),
    }
    assert!(
        t0.elapsed() < cfg.deadline + Duration::from_secs(15),
        "crash detection took {:?}, deadline was {:?}",
        t0.elapsed(),
        cfg.deadline
    );
}

#[test]
fn unwritable_metrics_out_is_a_clean_cli_error() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_pyg2"))
        .args([
            "dist",
            "--nodes=100",
            "--parts=2",
            "--metrics-out=/nonexistent-dir/metrics.jsonl",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "bad --metrics-out must fail");
    assert_eq!(out.status.code(), Some(1), "clean error exit, not a panic abort");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("error:"), "stderr was: {stderr}");
    assert!(!stderr.contains("panicked"), "stderr was: {stderr}");
}

#[test]
fn obs_check_rejects_file_truncated_mid_record() {
    let dir = tmp("truncated");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("metrics.jsonl");
    // No trailing newline: the tail of a snapshot record is missing.
    std::fs::write(&path, "{\"seq\":0,\"ts_ms\":1,\"final\":true,\"counters\":{}").unwrap();
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_pyg2"))
        .args(["obs-check", path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert_eq!(out.status.code(), Some(1));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("truncated"), "stderr was: {stderr}");
}
