//! Property-based tests over the data pipeline using the in-crate
//! mini-proptest framework: sampler invariants, batch layout invariants,
//! loader determinism, partition coverage — the coordinator-state
//! guarantees the paper's infrastructure relies on.

use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::datasets::temporal::{self, TemporalConfig};
use pyg2::loader::{Batch, ShapeBucket};
use pyg2::partition::ldg_partition;
use pyg2::sampler::{
    NeighborSampler, NeighborSamplerConfig, TemporalNeighborSampler, TemporalSamplerConfig,
    TemporalStrategy,
};
use pyg2::storage::{FeatureKey, GraphStore, InMemoryFeatureStore, InMemoryGraphStore};
use pyg2::util::proptest::{check, Gen, PairGen, UsizeRange, VecGen};
use pyg2::util::Rng;
use std::sync::Arc;

/// Generator for random sampler configurations.
struct SamplerCfgGen;

impl Gen for SamplerCfgGen {
    type Value = (Vec<usize>, bool, u64);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let hops = 1 + rng.index(3);
        let fanouts = (0..hops).map(|_| 1 + rng.index(6)).collect();
        (fanouts, rng.index(2) == 0, rng.next_u64())
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.0.len() > 1 {
            out.push((v.0[..1].to_vec(), v.1, v.2));
        }
        if v.0.iter().any(|&f| f > 1) {
            out.push((v.0.iter().map(|_| 1).collect(), v.1, v.2));
        }
        out
    }
}

#[test]
fn sampler_output_always_satisfies_invariants() {
    let g = sbm::generate(&SbmConfig { num_nodes: 400, seed: 1, ..Default::default() }).unwrap();
    let store = Arc::new(InMemoryGraphStore::from_graph(&g));
    check(11, &SamplerCfgGen, |(fanouts, disjoint, seed)| {
        let sampler = NeighborSampler::new(
            Arc::clone(&store),
            NeighborSamplerConfig {
                fanouts: fanouts.clone(),
                disjoint: *disjoint,
                seed: *seed,
                ..Default::default()
            },
        );
        let seeds: Vec<u32> = vec![seed.wrapping_mul(7) as u32 % 400, 3, 77];
        let sub = sampler.sample(&seeds, 0).map_err(|e| e.to_string())?;
        sub.check_invariants()?;
        // Fanout bound: each hop adds at most frontier * fanout edges.
        if sub.num_hops() != fanouts.len() {
            return Err(format!("hops {} != {}", sub.num_hops(), fanouts.len()));
        }
        // Every sampled edge id must reference a real graph edge whose
        // endpoints match the local relabeling.
        for (k, &eid) in sub.edge_ids.iter().enumerate() {
            let gs = g.edge_index.src()[eid as usize];
            let gd = g.edge_index.dst()[eid as usize];
            if sub.nodes[sub.row[k] as usize] != gs || sub.nodes[sub.col[k] as usize] != gd {
                return Err(format!("edge {eid} endpoint mismatch"));
            }
        }
        Ok(())
    });
}

#[test]
fn batch_assembly_respects_bucket_for_any_fanouts() {
    let g = sbm::generate(&SbmConfig { num_nodes: 300, seed: 2, ..Default::default() }).unwrap();
    let labels = g.y.clone().unwrap();
    let store = Arc::new(InMemoryGraphStore::from_graph(&g));
    let features = InMemoryFeatureStore::from_tensor(g.x.clone());
    check(13, &SamplerCfgGen, |(fanouts, _, seed)| {
        let bucket = ShapeBucket::for_sampling(4, fanouts);
        let sampler = NeighborSampler::new(
            Arc::clone(&store),
            NeighborSamplerConfig { fanouts: fanouts.clone(), seed: *seed, ..Default::default() },
        );
        let sub = sampler.sample(&[1, 2, 3, 4], 9).map_err(|e| e.to_string())?;
        let batch = Batch::assemble(sub, &features, &FeatureKey::default_x(), Some(&labels), &bucket)
            .map_err(|e| e.to_string())?;
        batch.check_invariants()?;
        // Trim prefix property: the first edge_cum[h] edge slots contain
        // exactly the real edges of hops <= h+1 (plus padding).
        for h in 1..=bucket.num_hops() {
            let (lo, hi) = bucket.edge_region(h);
            let real_in_region = batch.mask[lo..hi].iter().filter(|&&m| m > 0.0).count();
            let expected = if h == 1 {
                batch.sub.edge_offsets[0]
            } else {
                batch.sub.edge_offsets[h - 1] - batch.sub.edge_offsets[h - 2]
            };
            if real_in_region != expected {
                return Err(format!("hop {h}: {real_in_region} real edges, want {expected}"));
            }
        }
        Ok(())
    });
}

#[test]
fn temporal_sampler_never_leaks_future_for_any_strategy() {
    let g = temporal::generate(&TemporalConfig {
        num_nodes: 150,
        num_events: 1500,
        ..Default::default()
    })
    .unwrap();
    let etimes = g.edge_time.clone().unwrap();
    let store = Arc::new(InMemoryGraphStore::from_graph(&g));
    let gen = PairGen(
        VecGen { elem: UsizeRange { lo: 0, hi: 149 }, max_len: 6 },
        UsizeRange { lo: 0, hi: 1500 },
    );
    check(17, &gen, |(seed_nodes, t0)| {
        if seed_nodes.is_empty() {
            return Ok(());
        }
        for strategy in [
            TemporalStrategy::Uniform,
            TemporalStrategy::MostRecent,
            TemporalStrategy::Annealing { tau: 100.0 },
        ] {
            let sampler = TemporalNeighborSampler::new(
                Arc::clone(&store),
                TemporalSamplerConfig { fanouts: vec![4, 4], strategy, seed: 3 },
            );
            let seeds: Vec<u32> = seed_nodes.iter().map(|&s| s as u32).collect();
            let times: Vec<i64> = seeds
                .iter()
                .enumerate()
                .map(|(i, _)| (*t0 as i64 + i as i64 * 37) % 1500)
                .collect();
            let sub = sampler.sample(&seeds, &times, 5).map_err(|e| e.to_string())?;
            sub.check_invariants()?;
            let batch = sub.batch.as_ref().ok_or("temporal must be disjoint")?;
            for (k, &eid) in sub.edge_ids.iter().enumerate() {
                let tree = batch[sub.col[k] as usize] as usize;
                if etimes[eid as usize] > times[tree] {
                    return Err(format!(
                        "strategy {strategy:?}: edge t={} leaked past seed t={}",
                        etimes[eid as usize], times[tree]
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn partition_covers_all_nodes_for_any_part_count() {
    let g = sbm::generate(&SbmConfig { num_nodes: 500, seed: 3, ..Default::default() }).unwrap();
    check(19, &UsizeRange { lo: 1, hi: 16 }, |&parts| {
        let p = ldg_partition(&g.edge_index, parts, 1.2).map_err(|e| e.to_string())?;
        if p.assignment.len() != 500 {
            return Err("missing assignments".into());
        }
        if p.assignment.iter().any(|&a| a as usize >= parts) {
            return Err("assignment out of range".into());
        }
        let sizes = p.part_sizes();
        if sizes.iter().sum::<usize>() != 500 {
            return Err("sizes don't sum to n".into());
        }
        if parts > 1 && p.balance() > 1.35 {
            return Err(format!("imbalance {}", p.balance()));
        }
        Ok(())
    });
}

#[test]
fn csc_view_matches_naive_transpose_on_random_graphs() {
    struct GraphGen;
    impl Gen for GraphGen {
        type Value = (usize, Vec<(usize, usize)>);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            let n = 2 + rng.index(30);
            let e = rng.index(80);
            let edges = (0..e).map(|_| (rng.index(n), rng.index(n))).collect();
            (n, edges)
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            let mut out = Vec::new();
            if !v.1.is_empty() {
                out.push((v.0, v.1[..v.1.len() / 2].to_vec()));
                out.push((v.0, v.1[1..].to_vec()));
            }
            out
        }
    }
    check(23, &GraphGen, |(n, edges)| {
        let src: Vec<u32> = edges.iter().map(|&(s, _)| s as u32).collect();
        let dst: Vec<u32> = edges.iter().map(|&(_, d)| d as u32).collect();
        let ei = pyg2::graph::EdgeIndex::new(src.clone(), dst.clone(), *n)
            .map_err(|e| e.to_string())?;
        let csc = ei.csc();
        // Naive: in-neighbors of v = all src where dst == v.
        for v in 0..*n {
            let mut want: Vec<u32> = edges
                .iter()
                .filter(|&&(_, d)| d == v)
                .map(|&(s, _)| s as u32)
                .collect();
            let mut got: Vec<u32> = csc.neighbors(v).to_vec();
            want.sort_unstable();
            got.sort_unstable();
            if want != got {
                return Err(format!("node {v}: {got:?} != {want:?}"));
            }
        }
        Ok(())
    });
}
