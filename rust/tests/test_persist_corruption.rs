//! Corrupt-input hardening of the persist subsystem: truncated,
//! bit-flipped, or otherwise tampered bundles and `.pygf` shards must
//! surface as `Error`s — never panics, never silent misreads. Every
//! structural byte of the manifest is flipped in turn, and each shard
//! file kind is truncated and magic-flipped.

use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::dist::{PartitionedFeatureStore, PartitionedGraphStore};
use pyg2::partition::ldg_partition;
use pyg2::persist::{write_bundle, Bundle, LruConfig};
use pyg2::storage::DEFAULT_GROUP;
use std::path::{Path, PathBuf};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("pyg2_persist_corruption").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn toy_bundle(name: &str) -> Bundle {
    let g = sbm::generate(&SbmConfig { num_nodes: 80, seed: 9, ..Default::default() }).unwrap();
    let p = ldg_partition(&g.edge_index, 3, 1.1).unwrap();
    write_bundle(tmp(name), &g, &p).unwrap()
}

/// Open + fully mount a bundle directory, returning the first error.
/// Exercises every load path a corrupt byte could hide in: manifest
/// parsing, ownership vectors, labels, adjacency shards, feature
/// shards.
fn open_and_mount(dir: &Path) -> pyg2::Result<()> {
    let bundle = Bundle::open(dir)?;
    PartitionedGraphStore::mount(&bundle, 0)?;
    PartitionedFeatureStore::mount(&bundle, 0, LruConfig::default())?;
    bundle.load_labels(DEFAULT_GROUP)?;
    Ok(())
}

#[test]
fn pristine_bundle_mounts() {
    let bundle = toy_bundle("pristine");
    open_and_mount(bundle.dir()).unwrap();
}

#[test]
fn every_manifest_byte_flip_is_rejected() {
    // Flipping any manifest byte either breaks the JSON, renames a
    // referenced path/type (missing file, or caught by the shard
    // identity stamps / adjacency ownership checks), or desyncs a count
    // some validator cross-checks. All of it must surface as an Error
    // from open or mount — never a panic. The one exception is the
    // relation *name*: it is pure metadata with no structural echo, so
    // a flip there yields a well-formed bundle for a different relation
    // (the pipeline then fails to find its edge type at sampling time).
    let bundle = toy_bundle("manifest_flip");
    let path = bundle.dir().join("manifest.json");
    let pristine = std::fs::read(&path).unwrap();
    let text = String::from_utf8(pristine.clone()).unwrap();
    let rel_value = {
        let start = text.find(r#""rel":""#).unwrap() + 7;
        let end = start + text[start..].find('"').unwrap();
        start..end
    };
    for i in 0..pristine.len() {
        if rel_value.contains(&i) {
            continue;
        }
        let mut evil = pristine.clone();
        evil[i] ^= 0x01;
        std::fs::write(&path, &evil).unwrap();
        assert!(
            open_and_mount(bundle.dir()).is_err(),
            "manifest byte {i} ({:?} -> {:?}) must not mount",
            pristine[i] as char,
            evil[i] as char
        );
    }
    std::fs::write(&path, &pristine).unwrap();
    open_and_mount(bundle.dir()).unwrap();
}

/// All shard-ish files of the bundle (everything but the manifest).
fn shard_files(dir: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        for e in std::fs::read_dir(&d).unwrap().flatten() {
            let p = e.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.file_name().is_some_and(|n| n != "manifest.json") {
                out.push(p);
            }
        }
    }
    assert!(out.len() >= 7, "assign + 3 feature shards + 3 adjacency shards: {out:?}");
    out
}

#[test]
fn truncated_shard_files_are_rejected() {
    let bundle = toy_bundle("truncate");
    for file in shard_files(bundle.dir()) {
        let pristine = std::fs::read(&file).unwrap();
        for keep in [pristine.len() - 1, pristine.len() / 2, 10, 0] {
            std::fs::write(&file, &pristine[..keep.min(pristine.len())]).unwrap();
            assert!(
                open_and_mount(bundle.dir()).is_err(),
                "{} truncated to {keep} bytes must not mount",
                file.display()
            );
        }
        std::fs::write(&file, &pristine).unwrap();
    }
    open_and_mount(bundle.dir()).unwrap();
}

#[test]
fn extended_shard_files_are_rejected() {
    // Exact-size validation: trailing garbage is as suspicious as
    // truncation.
    let bundle = toy_bundle("extend");
    for file in shard_files(bundle.dir()) {
        let pristine = std::fs::read(&file).unwrap();
        let mut longer = pristine.clone();
        longer.extend_from_slice(&[0u8; 5]);
        std::fs::write(&file, &longer).unwrap();
        assert!(
            open_and_mount(bundle.dir()).is_err(),
            "{} with trailing bytes must not mount",
            file.display()
        );
        std::fs::write(&file, &pristine).unwrap();
    }
    open_and_mount(bundle.dir()).unwrap();
}

#[test]
fn header_bit_flips_in_shard_files_are_rejected() {
    // Flip every byte of each file's structural header (magic + counts):
    // all of them are load-bearing, so every flip must error.
    let bundle = toy_bundle("header_flip");
    for file in shard_files(bundle.dir()) {
        let pristine = std::fs::read(&file).unwrap();
        for i in 0..16.min(pristine.len()) {
            let mut evil = pristine.clone();
            evil[i] ^= 0x01;
            std::fs::write(&file, &evil).unwrap();
            assert!(
                open_and_mount(bundle.dir()).is_err(),
                "{} header byte {i} flipped must not mount",
                file.display()
            );
        }
        std::fs::write(&file, &pristine).unwrap();
    }
    open_and_mount(bundle.dir()).unwrap();
}

#[test]
fn every_adjacency_byte_flip_is_rejected() {
    // Adjacency shards have no slack: header fields are size-checked,
    // indptr is span/monotonicity-checked, perm must cover the edge set
    // exactly (in- and out-shards independently), and every out-edge
    // entry must agree with the COO the in-shards define. So *any*
    // single-bit flip anywhere in a shard file must fail the mount.
    let g = sbm::generate(&SbmConfig { num_nodes: 30, seed: 4, ..Default::default() }).unwrap();
    let p = ldg_partition(&g.edge_index, 2, 1.1).unwrap();
    let bundle = write_bundle(tmp("adj_payload"), &g, &p).unwrap();
    let shard = bundle.dir().join("adj/0__default__to___default.p0.pyga");
    let pristine = std::fs::read(&shard).unwrap();
    for i in 0..pristine.len() {
        let mut evil = pristine.clone();
        evil[i] ^= 0x01;
        std::fs::write(&shard, &evil).unwrap();
        assert!(
            open_and_mount(bundle.dir()).is_err(),
            "adjacency byte {i} of {} flipped must not mount",
            pristine.len()
        );
    }
    std::fs::write(&shard, &pristine).unwrap();
    open_and_mount(bundle.dir()).unwrap();
}

#[test]
fn out_of_range_assignment_is_rejected() {
    // Corrupt the payload itself: an ownership entry pointing at a
    // partition that does not exist must be caught at mount.
    let bundle = toy_bundle("bad_owner");
    let assign = bundle.dir().join("nodes/0__default.assign");
    let mut bytes = std::fs::read(&assign).unwrap();
    // First payload entry (after the 16-byte header) -> partition 200.
    bytes[16..20].copy_from_slice(&200u32.to_le_bytes());
    std::fs::write(&assign, &bytes).unwrap();
    assert!(open_and_mount(bundle.dir()).is_err());
}

#[test]
fn feature_shard_with_wrong_width_is_rejected() {
    // A forged shard with the correct identity stamp and row count but
    // a different feature dim must fail the mount's schema check — a
    // width-trusting consumer would otherwise misread it silently.
    use pyg2::storage::{FeatureKey, FeatureStore, FileFeatureStore, FileFeatureWriter};
    use pyg2::tensor::Tensor;

    let bundle = toy_bundle("wrong_width");
    let path = bundle.dir().join("features/0__default.p1.pygf");
    let rows = FileFeatureStore::open(&path)
        .unwrap()
        .num_rows(&FeatureKey::default_x())
        .unwrap();
    let mut w = FileFeatureWriter::new(&path);
    // Shard 0 has the SBM's 64-dim features; this one claims 2 dims.
    w.put(FeatureKey::default_x(), Tensor::zeros(vec![rows, 2]));
    w.put(
        FeatureKey::new(DEFAULT_GROUP, "__bundle_shard"),
        Tensor::new(vec![1, 2], vec![0.0, 1.0]).unwrap(),
    );
    w.finish().unwrap();
    assert!(open_and_mount(bundle.dir()).is_err());
}

#[test]
fn missing_shard_files_are_rejected() {
    let bundle = toy_bundle("missing");
    for file in shard_files(bundle.dir()) {
        let pristine = std::fs::read(&file).unwrap();
        std::fs::remove_file(&file).unwrap();
        assert!(
            open_and_mount(bundle.dir()).is_err(),
            "{} missing must not mount",
            file.display()
        );
        std::fs::write(&file, &pristine).unwrap();
    }
    open_and_mount(bundle.dir()).unwrap();
}
