//! Corrupt-input hardening of the persist subsystem: truncated,
//! bit-flipped, or otherwise tampered bundles and `.pygf` shards must
//! surface as `Error`s — never panics, never silent misreads. Every
//! structural byte of the manifest is flipped in turn, and each shard
//! file kind is truncated and magic-flipped.

use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::dist::{PartitionedFeatureStore, PartitionedGraphStore};
use pyg2::partition::ldg_partition;
use pyg2::persist::{write_bundle, Bundle, LruConfig};
use pyg2::storage::DEFAULT_GROUP;
use std::path::{Path, PathBuf};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("pyg2_persist_corruption").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn toy_bundle(name: &str) -> Bundle {
    let g = sbm::generate(&SbmConfig { num_nodes: 80, seed: 9, ..Default::default() }).unwrap();
    let p = ldg_partition(&g.edge_index, 3, 1.1).unwrap();
    write_bundle(tmp(name), &g, &p).unwrap()
}

/// Open + fully mount a bundle directory, returning the first error.
/// Exercises every load path a corrupt byte could hide in: manifest
/// parsing, ownership vectors, labels, adjacency shards, feature
/// shards.
fn open_and_mount(dir: &Path) -> pyg2::Result<()> {
    let bundle = Bundle::open(dir)?;
    PartitionedGraphStore::mount(&bundle, 0)?;
    PartitionedFeatureStore::mount(&bundle, 0, LruConfig::default())?;
    bundle.load_labels(DEFAULT_GROUP)?;
    Ok(())
}

#[test]
fn pristine_bundle_mounts() {
    let bundle = toy_bundle("pristine");
    open_and_mount(bundle.dir()).unwrap();
}

#[test]
fn every_manifest_byte_flip_is_rejected() {
    // Flipping any manifest byte either breaks the JSON, renames a
    // referenced path/type (missing file, or caught by the shard
    // identity stamps / adjacency ownership checks), or desyncs a count
    // some validator cross-checks. All of it must surface as an Error
    // from open or mount — never a panic. The one exception is the
    // relation *name*: it is pure metadata with no structural echo, so
    // a flip there yields a well-formed bundle for a different relation
    // (the pipeline then fails to find its edge type at sampling time).
    let bundle = toy_bundle("manifest_flip");
    let path = bundle.dir().join("manifest.json");
    let pristine = std::fs::read(&path).unwrap();
    let text = String::from_utf8(pristine.clone()).unwrap();
    let rel_value = {
        let start = text.find(r#""rel":""#).unwrap() + 7;
        let end = start + text[start..].find('"').unwrap();
        start..end
    };
    for i in 0..pristine.len() {
        if rel_value.contains(&i) {
            continue;
        }
        let mut evil = pristine.clone();
        evil[i] ^= 0x01;
        std::fs::write(&path, &evil).unwrap();
        assert!(
            open_and_mount(bundle.dir()).is_err(),
            "manifest byte {i} ({:?} -> {:?}) must not mount",
            pristine[i] as char,
            evil[i] as char
        );
    }
    std::fs::write(&path, &pristine).unwrap();
    open_and_mount(bundle.dir()).unwrap();
}

/// All shard-ish files of the bundle (everything but the manifest).
fn shard_files(dir: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        for e in std::fs::read_dir(&d).unwrap().flatten() {
            let p = e.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.file_name().is_some_and(|n| n != "manifest.json") {
                out.push(p);
            }
        }
    }
    assert!(out.len() >= 7, "assign + 3 feature shards + 3 adjacency shards: {out:?}");
    out
}

#[test]
fn truncated_shard_files_are_rejected() {
    let bundle = toy_bundle("truncate");
    for file in shard_files(bundle.dir()) {
        let pristine = std::fs::read(&file).unwrap();
        for keep in [pristine.len() - 1, pristine.len() / 2, 10, 0] {
            std::fs::write(&file, &pristine[..keep.min(pristine.len())]).unwrap();
            assert!(
                open_and_mount(bundle.dir()).is_err(),
                "{} truncated to {keep} bytes must not mount",
                file.display()
            );
        }
        std::fs::write(&file, &pristine).unwrap();
    }
    open_and_mount(bundle.dir()).unwrap();
}

#[test]
fn extended_shard_files_are_rejected() {
    // Exact-size validation: trailing garbage is as suspicious as
    // truncation.
    let bundle = toy_bundle("extend");
    for file in shard_files(bundle.dir()) {
        let pristine = std::fs::read(&file).unwrap();
        let mut longer = pristine.clone();
        longer.extend_from_slice(&[0u8; 5]);
        std::fs::write(&file, &longer).unwrap();
        assert!(
            open_and_mount(bundle.dir()).is_err(),
            "{} with trailing bytes must not mount",
            file.display()
        );
        std::fs::write(&file, &pristine).unwrap();
    }
    open_and_mount(bundle.dir()).unwrap();
}

#[test]
fn header_bit_flips_in_shard_files_are_rejected() {
    // Flip every byte of each file's structural header (magic + counts):
    // all of them are load-bearing, so every flip must error.
    let bundle = toy_bundle("header_flip");
    for file in shard_files(bundle.dir()) {
        let pristine = std::fs::read(&file).unwrap();
        for i in 0..16.min(pristine.len()) {
            let mut evil = pristine.clone();
            evil[i] ^= 0x01;
            std::fs::write(&file, &evil).unwrap();
            assert!(
                open_and_mount(bundle.dir()).is_err(),
                "{} header byte {i} flipped must not mount",
                file.display()
            );
        }
        std::fs::write(&file, &pristine).unwrap();
    }
    open_and_mount(bundle.dir()).unwrap();
}

#[test]
fn every_adjacency_byte_flip_is_rejected() {
    // Adjacency shards have no slack: header fields are size-checked,
    // indptr is span/monotonicity-checked, perm must cover the edge set
    // exactly (in- and out-shards independently), and every out-edge
    // entry must agree with the COO the in-shards define. So *any*
    // single-bit flip anywhere in a shard file must fail the mount.
    let g = sbm::generate(&SbmConfig { num_nodes: 30, seed: 4, ..Default::default() }).unwrap();
    let p = ldg_partition(&g.edge_index, 2, 1.1).unwrap();
    let bundle = write_bundle(tmp("adj_payload"), &g, &p).unwrap();
    let shard = bundle.dir().join("adj/0__default__to___default.p0.pyga");
    let pristine = std::fs::read(&shard).unwrap();
    for i in 0..pristine.len() {
        let mut evil = pristine.clone();
        evil[i] ^= 0x01;
        std::fs::write(&shard, &evil).unwrap();
        assert!(
            open_and_mount(bundle.dir()).is_err(),
            "adjacency byte {i} of {} flipped must not mount",
            pristine.len()
        );
    }
    std::fs::write(&shard, &pristine).unwrap();
    open_and_mount(bundle.dir()).unwrap();
}

#[test]
fn out_of_range_assignment_is_rejected() {
    // Corrupt the payload itself: an ownership entry pointing at a
    // partition that does not exist must be caught at mount.
    let bundle = toy_bundle("bad_owner");
    let assign = bundle.dir().join("nodes/0__default.assign");
    let mut bytes = std::fs::read(&assign).unwrap();
    // First payload entry (after the 16-byte header) -> partition 200.
    bytes[16..20].copy_from_slice(&200u32.to_le_bytes());
    std::fs::write(&assign, &bytes).unwrap();
    assert!(open_and_mount(bundle.dir()).is_err());
}

#[test]
fn feature_shard_with_wrong_width_is_rejected() {
    // A forged shard with the correct identity stamp and row count but
    // a different feature dim must fail the mount's schema check — a
    // width-trusting consumer would otherwise misread it silently.
    use pyg2::storage::{FeatureKey, FeatureStore, FileFeatureStore, FileFeatureWriter};
    use pyg2::tensor::Tensor;

    let bundle = toy_bundle("wrong_width");
    let path = bundle.dir().join("features/0__default.p1.pygf");
    let rows = FileFeatureStore::open(&path)
        .unwrap()
        .num_rows(&FeatureKey::default_x())
        .unwrap();
    let mut w = FileFeatureWriter::new(&path);
    // Shard 0 has the SBM's 64-dim features; this one claims 2 dims.
    w.put(FeatureKey::default_x(), Tensor::zeros(vec![rows, 2]));
    w.put(
        FeatureKey::new(DEFAULT_GROUP, "__bundle_shard"),
        Tensor::new(vec![1, 2], vec![0.0, 1.0]).unwrap(),
    );
    w.finish().unwrap();
    assert!(open_and_mount(bundle.dir()).is_err());
}

#[test]
fn missing_shard_files_are_rejected() {
    let bundle = toy_bundle("missing");
    for file in shard_files(bundle.dir()) {
        let pristine = std::fs::read(&file).unwrap();
        std::fs::remove_file(&file).unwrap();
        assert!(
            open_and_mount(bundle.dir()).is_err(),
            "{} missing must not mount",
            file.display()
        );
        std::fs::write(&file, &pristine).unwrap();
    }
    open_and_mount(bundle.dir()).unwrap();
}

// ---------------------------------------------------------------------
// Demand-paged adjacency (`--page-adj`): the same corruption classes
// must fail **at open or first touch** — never a panic, never silent
// wrong neighbors — even though the paged path never decodes a shard
// into RAM.
// ---------------------------------------------------------------------

use pyg2::persist::{AdjBuf, AdjCache, IoBackend};
use pyg2::storage::GraphStore;
use std::sync::Arc;

/// Open + mount a bundle with paged adjacency and *touch every
/// neighbor list* of every edge type, in and out — exercising both the
/// open-time validation (header, stamp, checksum, indptr stream) and
/// the first-touch validation (indptr pair, id bounds) a corrupt byte
/// could hide behind.
fn open_and_mount_paged(dir: &Path) -> pyg2::Result<()> {
    open_and_mount_paged_via(dir, IoBackend::Pread)
}

/// [`open_and_mount_paged`] under a chosen positioned-read backend —
/// `--io-backend mmap` must reject exactly what pread rejects.
fn open_and_mount_paged_via(dir: &Path, backend: IoBackend) -> pyg2::Result<()> {
    let bundle = Bundle::open(dir)?;
    let gs = PartitionedGraphStore::mount_paged_with(
        &bundle,
        0,
        Arc::new(AdjCache::new(1 << 20)),
        backend,
    )?;
    let mut buf = AdjBuf::default();
    for et in gs.edge_types() {
        let es = gs.edges_of(&et)?;
        for v in 0..gs.num_nodes(&et.dst)? {
            es.read_in_timed(v as u32, &mut buf, true)?;
        }
        for v in 0..gs.num_nodes(&et.src)? {
            es.read_out(v as u32, &mut buf)?;
        }
    }
    PartitionedFeatureStore::mount(&bundle, 0, LruConfig::default())?;
    bundle.load_labels(DEFAULT_GROUP)?;
    Ok(())
}

/// 64-bit FNV-1a (the shard payload checksum) — local copy for forging
/// "valid-checksum, bad-structure" shards in the tests below.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

const ADJ_HEADER: usize = 8 + 7 * 8;

#[test]
fn pristine_bundle_mounts_paged() {
    let bundle = toy_bundle("paged_pristine");
    for backend in [IoBackend::Pread, IoBackend::Mmap] {
        open_and_mount_paged_via(bundle.dir(), backend).unwrap();
    }
}

#[test]
fn every_adjacency_byte_flip_is_rejected_by_the_paged_mount() {
    // The paged reader never decodes the payload at mount, but the
    // open-time streaming checksum gives it the same every-byte-flip
    // guarantee as the resident reader's structural cross-validation.
    let g = sbm::generate(&SbmConfig { num_nodes: 30, seed: 4, ..Default::default() }).unwrap();
    let p = ldg_partition(&g.edge_index, 2, 1.1).unwrap();
    let bundle = write_bundle(tmp("paged_adj_payload"), &g, &p).unwrap();
    let shard = bundle.dir().join("adj/0__default__to___default.p0.pyga");
    let pristine = std::fs::read(&shard).unwrap();
    for i in 0..pristine.len() {
        let mut evil = pristine.clone();
        evil[i] ^= 0x01;
        std::fs::write(&shard, &evil).unwrap();
        assert!(
            open_and_mount_paged(bundle.dir()).is_err(),
            "adjacency byte {i} of {} flipped must not mount paged",
            pristine.len()
        );
    }
    std::fs::write(&shard, &pristine).unwrap();
    open_and_mount_paged(bundle.dir()).unwrap();
}

#[test]
fn repointed_adjacency_shards_are_rejected_by_both_mounts() {
    // Swap two structurally valid shard files: each carries the other
    // slot's identity stamp, so both the resident and the paged open
    // must reject the bundle before any neighbor list is served.
    let bundle = toy_bundle("paged_repoint");
    let a = bundle.dir().join("adj/0__default__to___default.p0.pyga");
    let b = bundle.dir().join("adj/0__default__to___default.p1.pyga");
    let (ba, bb) = (std::fs::read(&a).unwrap(), std::fs::read(&b).unwrap());
    std::fs::write(&a, &bb).unwrap();
    std::fs::write(&b, &ba).unwrap();
    assert!(open_and_mount(bundle.dir()).is_err(), "resident mount must reject the swap");
    for backend in [IoBackend::Pread, IoBackend::Mmap] {
        assert!(
            open_and_mount_paged_via(bundle.dir(), backend).is_err(),
            "paged mount ({backend}) must reject the swap"
        );
    }
    std::fs::write(&a, &ba).unwrap();
    std::fs::write(&b, &bb).unwrap();
    open_and_mount_paged(bundle.dir()).unwrap();
}

#[test]
fn forged_out_of_bounds_indptr_is_rejected_at_paged_open() {
    // Forge a shard whose checksum is valid but whose csc indptr jumps
    // past the header's nnz: the open-time indptr stream must catch it
    // (a checksum alone would wave it through).
    let bundle = toy_bundle("paged_indptr");
    let shard = bundle.dir().join("adj/0__default__to___default.p0.pyga");
    let mut bytes = std::fs::read(&shard).unwrap();
    // Second csc indptr entry (the first node's list end).
    let off = ADJ_HEADER + 8;
    bytes[off..off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
    let hash = fnv1a(&bytes[ADJ_HEADER..]);
    bytes[56..64].copy_from_slice(&hash.to_le_bytes());
    std::fs::write(&shard, &bytes).unwrap();
    for backend in [IoBackend::Pread, IoBackend::Mmap] {
        assert!(open_and_mount_paged_via(bundle.dir(), backend).is_err(), "{backend}");
    }
}

#[test]
fn truncated_indices_mid_run_fail_at_first_touch() {
    // Truncation *after* the mount validated the file: the positioned
    // read lands past EOF on first touch and must surface as an Error,
    // never a panic or a short/garbage neighbor list.
    let bundle = toy_bundle("paged_midrun");
    let gs = PartitionedGraphStore::mount_paged(&bundle, 0, Arc::new(AdjCache::new(1 << 20)))
        .unwrap();
    let shard = bundle.dir().join("adj/0__default__to___default.p0.pyga");
    let pristine = std::fs::read(&shard).unwrap();
    std::fs::write(&shard, &pristine[..pristine.len() / 2]).unwrap();
    let et = pyg2::storage::default_edge_type();
    let es = gs.edges_of(&et).unwrap();
    let mut buf = AdjBuf::default();
    let mut outcomes = (0usize, 0usize); // (served, errored)
    for v in 0..80u32 {
        match es.read_in(v, &mut buf) {
            Ok(_) => outcomes.0 += 1,
            Err(_) => outcomes.1 += 1,
        }
    }
    assert!(outcomes.1 > 0, "mid-run truncation must error on some first touch");
    std::fs::write(&shard, &pristine).unwrap();
}

#[test]
fn wrong_width_files_are_rejected_at_paged_open() {
    // A `.pyga` slot pointing at a different-width array file (here an
    // i64 timestamp array) must die on the magic/size checks, and a
    // timestamp slot pointing at a u32 file likewise — "wrong-width
    // reads" can never silently reinterpret bytes.
    let mut g = sbm::generate(&SbmConfig { num_nodes: 40, seed: 6, ..Default::default() }).unwrap();
    g.edge_time = Some((0..g.num_edges() as i64).collect());
    let p = ldg_partition(&g.edge_index, 2, 1.1).unwrap();
    let dir = tmp("paged_width");
    let bundle = write_bundle(&dir, &g, &p).unwrap();
    open_and_mount_paged(bundle.dir()).unwrap();

    let shard = bundle.dir().join("adj/0__default__to___default.p0.pyga");
    let time = bundle.dir().join("adj/0__default__to___default.time");
    let (shard_bytes, time_bytes) =
        (std::fs::read(&shard).unwrap(), std::fs::read(&time).unwrap());

    std::fs::write(&shard, &time_bytes).unwrap();
    assert!(open_and_mount_paged(bundle.dir()).is_err(), "i64 array as .pyga rejected");
    std::fs::write(&shard, &shard_bytes).unwrap();

    std::fs::write(&time, &shard_bytes).unwrap();
    assert!(open_and_mount_paged(bundle.dir()).is_err(), ".pyga as time file rejected");
    // A *truncated* time file is caught by the exact-size check too.
    std::fs::write(&time, &time_bytes[..time_bytes.len() - 3]).unwrap();
    assert!(open_and_mount_paged(bundle.dir()).is_err(), "truncated time file rejected");
    std::fs::write(&time, &time_bytes).unwrap();
    open_and_mount_paged(bundle.dir()).unwrap();
}

#[test]
fn paged_mount_rejects_missing_and_truncated_adjacency_files() {
    let bundle = toy_bundle("paged_missing");
    for backend in [IoBackend::Pread, IoBackend::Mmap] {
        for file in shard_files(bundle.dir()) {
            if !file.extension().is_some_and(|e| e == "pyga") {
                continue;
            }
            let pristine = std::fs::read(&file).unwrap();
            std::fs::remove_file(&file).unwrap();
            assert!(
                open_and_mount_paged_via(bundle.dir(), backend).is_err(),
                "{} missing ({backend})",
                file.display()
            );
            std::fs::write(&file, &pristine[..pristine.len() - 1]).unwrap();
            assert!(
                open_and_mount_paged_via(bundle.dir(), backend).is_err(),
                "{} truncated ({backend})",
                file.display()
            );
            let mut longer = pristine.clone();
            longer.push(0);
            std::fs::write(&file, &longer).unwrap();
            assert!(
                open_and_mount_paged_via(bundle.dir(), backend).is_err(),
                "{} extended ({backend})",
                file.display()
            );
            std::fs::write(&file, &pristine).unwrap();
        }
        open_and_mount_paged_via(bundle.dir(), backend).unwrap();
    }
}

#[test]
fn mmap_backend_rejects_header_flips_and_serves_pristine_lists() {
    // The mmap backend shares every open-time validator with pread —
    // spot-check the structural header flips (the cheap, load-bearing
    // prefix) and the checksum tail under `--io-backend mmap`, then
    // confirm a pristine mount still serves every neighbor list.
    let g = sbm::generate(&SbmConfig { num_nodes: 30, seed: 4, ..Default::default() }).unwrap();
    let p = ldg_partition(&g.edge_index, 2, 1.1).unwrap();
    let bundle = write_bundle(tmp("mmap_header_flip"), &g, &p).unwrap();
    let shard = bundle.dir().join("adj/0__default__to___default.p0.pyga");
    let pristine = std::fs::read(&shard).unwrap();
    // Every header byte, plus a stride through the payload (the
    // streaming checksum covers it byte-for-byte).
    let flips = (0..ADJ_HEADER.min(pristine.len()))
        .chain((ADJ_HEADER..pristine.len()).step_by(7));
    for i in flips {
        let mut evil = pristine.clone();
        evil[i] ^= 0x01;
        std::fs::write(&shard, &evil).unwrap();
        assert!(
            open_and_mount_paged_via(bundle.dir(), IoBackend::Mmap).is_err(),
            "mmap mount must reject byte {i} flipped"
        );
    }
    std::fs::write(&shard, &pristine).unwrap();
    open_and_mount_paged_via(bundle.dir(), IoBackend::Mmap).unwrap();
}
