//! Explainability demo (§2.4, Figure 2): train a GCN, attribute its
//! predictions to edges via gradient saliency, and validate the
//! explanation with fidelity⁺/⁻ — plus a homophily check: on an SBM
//! graph, highly-attributed edges should disproportionately connect
//! same-community nodes.
//!
//! Run: `cargo run --release --example explain_demo`.

use pyg2::coordinator::{default_loader, TrainConfig, Trainer};
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::explain::{ExplainAlgorithm, Explainer};
use pyg2::runtime::Engine;

fn main() -> pyg2::Result<()> {
    pyg2::util::logging::init();
    let engine = Engine::load("artifacts")?;
    let b = engine.manifest().bucket.clone();

    let graph = sbm::generate(&SbmConfig {
        num_nodes: 1500,
        num_blocks: b.c,
        feature_dim: b.f,
        feature_signal: 1.5,
        seed: 3,
        ..Default::default()
    })?;
    let loader = default_loader(&engine, &graph, (0..1024).collect(), 2);
    println!("training gcn for the explanation target ...");
    let report = Trainer::new(
        &engine,
        TrainConfig { epochs: 6, log_every: 0, ..Default::default() },
    )
    .train(&loader)?;
    println!(
        "trained: final acc {:.3}",
        report.recent_accuracy(8)
    );

    let explainer = Explainer::new(&engine, "gcn");
    let batch = loader.iter_epoch(500).next().unwrap()?;

    // Gradient saliency (one backward pass).
    let ex = explainer.explain(&report.final_params, &batch, ExplainAlgorithm::Saliency)?;
    let (fp, fm) = explainer.fidelity(&report.final_params, &batch, &ex, 48)?;
    println!("\nsaliency explanation:");
    println!("  fidelity+ (drop top-48 edges):    {fp:.3}  (higher = explanation necessary)");
    println!("  fidelity- (drop bottom-48 edges): {fm:.3}  (lower  = explanation sufficient)");

    // Homophily of top-attributed edges vs all real edges.
    let labels = graph.y.as_ref().unwrap();
    let same_label_frac = |edges: &[usize]| {
        let mut same = 0;
        let mut total = 0;
        for &k in edges {
            // Map padded endpoints back to global node ids.
            let r = batch.row[k] as u32;
            let c = batch.col[k] as u32;
            let find = |p: u32| {
                batch
                    .node_pos
                    .iter()
                    .position(|&x| x == p)
                    .map(|i| batch.sub.nodes[i])
            };
            if let (Some(gr), Some(gc)) = (find(r), find(c)) {
                total += 1;
                if labels[gr as usize] == labels[gc as usize] {
                    same += 1;
                }
            }
        }
        same as f64 / total.max(1) as f64
    };
    let top = ex.top_edges(48);
    let all_real: Vec<usize> = (0..batch.mask.len()).filter(|&k| batch.mask[k] > 0.0).collect();
    let (h_top, h_all) = (same_label_frac(&top), same_label_frac(&all_real));
    println!("  homophily of top-48 attributed edges: {h_top:.3} (all real edges: {h_all:.3})");

    // Occlusion baseline agrees directionally with saliency (rank overlap).
    println!("\noclusion baseline (|E| forward passes) ...");
    let ex_occ = explainer.explain(&report.final_params, &batch, ExplainAlgorithm::Occlusion)?;
    let top_occ: std::collections::HashSet<usize> =
        ex_occ.top_edges(48).into_iter().collect();
    let overlap = top.iter().filter(|e| top_occ.contains(e)).count();
    println!("  top-48 overlap saliency vs occlusion: {overlap}/48");

    assert!(fp >= fm, "necessary edges must matter more than irrelevant ones");
    println!("explain_demo OK");
    Ok(())
}
