//! Relational Deep Learning end-to-end (§3.1, DESIGN.md E2E).
//!
//! Synthesizes an e-commerce relational database (users / products /
//! transactions / reviews), converts it to a heterogeneous *temporal*
//! graph (tables → node types, FKs → edge types, TensorFrame-encoded
//! multi-modal features), builds the churn training table ("will this
//! user transact after the horizon?"), and trains the grouped-matmul
//! hetero GNN through temporal, leakage-free, training-table-driven
//! subgraph loading.
//!
//! Run: `cargo run --release --example rdl_training`.

use pyg2::datasets::relational::{self, RelationalConfig};
use pyg2::loader::SeedTableLoader;
use pyg2::nn::ParamStore;
use pyg2::rdl::{build_training_table, database_to_graph, pack_rdl_batch, RdlShapes};
use pyg2::runtime::Engine;
use pyg2::sampler::HeteroSamplerConfig;
use pyg2::storage::InMemoryGraphStore;
use std::sync::Arc;

fn main() -> pyg2::Result<()> {
    pyg2::util::logging::init();
    let engine = Engine::load("artifacts")?;
    let shapes = RdlShapes::default();

    // 1. Synthesize the relational database.
    let db = relational::generate(&RelationalConfig::default())?;
    println!(
        "database: {} tables, horizon t={}",
        db.tables.len(),
        db.horizon
    );

    // 2. Database -> heterogeneous temporal graph.
    let graph = database_to_graph(&db, shapes.f_in)?;
    println!(
        "hetero graph: {} node types, {} edge types, {} nodes, {} edges",
        graph.num_node_types(),
        graph.num_edge_types(),
        graph.total_nodes(),
        graph.total_edges()
    );

    // 3. Training table + temporal split.
    let table = build_training_table(&db)?;
    let pos: i64 = table.labels.iter().sum();
    println!(
        "training table: {} users, {:.1}% positive",
        table.len(),
        100.0 * pos as f64 / table.len() as f64
    );

    // 4. Seed-table loader: disjoint temporal hetero sampling at each
    // user's seed timestamp (no future leakage by construction).
    let store = Arc::new(InMemoryGraphStore::from_hetero(&graph));
    // Batch size is chosen so the worst-case typed expansion fits the
    // artifact's NT_pad=256 per-type budget (24 seeds x fanout [4,3]).
    let loader = SeedTableLoader::new(
        store,
        table,
        HeteroSamplerConfig { default_fanouts: vec![4, 3], ..Default::default() },
        24,
    );

    // 5. Train via the rdl_train artifact (Pallas grouped-matmul encoder).
    let mut params = ParamStore::init_for(engine.manifest(), "rdl_train", 3)?;
    let epochs = 8;
    println!("training rdl model for {epochs} epochs = {} steps ...", loader.num_batches() * epochs);
    let mut history: Vec<(f32, f32)> = Vec::new();
    for epoch in 0..epochs {
        for batch in loader.iter_epoch(epoch as u64) {
            let batch = batch?;
            batch.sub.check_invariants().map_err(pyg2::Error::Sampler)?;
            let inputs = pack_rdl_batch(&graph, &batch, &shapes)?;
            let out = engine.run_fused("rdl_train", &params.values(), &inputs)?;
            let loss = out[0].scalar_f32()?;
            // accuracy on real seeds
            let logits = out[1].to_tensor()?;
            let preds = pyg2::tensor::argmax_rows(&logits);
            let mut correct = 0;
            for (i, &l) in batch.labels.iter().enumerate() {
                if preds[i] as i64 == l {
                    correct += 1;
                }
            }
            let acc = correct as f32 / batch.labels.len() as f32;
            params.update_from_fused_output(&out)?;
            history.push((loss, acc));
        }
        let tail = &history[history.len().saturating_sub(4)..];
        let loss: f32 = tail.iter().map(|x| x.0).sum::<f32>() / tail.len() as f32;
        let acc: f32 = tail.iter().map(|x| x.1).sum::<f32>() / tail.len() as f32;
        println!("  epoch {epoch}: loss {loss:.4} acc {acc:.3}");
    }

    let first_loss = history[0].0;
    let final_acc: f32 =
        history[history.len().saturating_sub(8)..].iter().map(|x| x.1).sum::<f32>() / 8.0;
    println!(
        "\nrdl training: loss {first_loss:.3} -> {:.3}, final acc {final_acc:.3}",
        history.last().unwrap().0
    );
    assert!(final_acc > 0.6, "RDL model should beat the majority class");
    println!("rdl_training OK");
    Ok(())
}
