//! Quickstart: the end-to-end driver (DESIGN.md experiment E2E).
//!
//! Generates a Cora-scale SBM citation-graph substitute, trains a 3-layer
//! GCN through the full stack — multi-threaded neighbor sampling →
//! hop-aligned batch assembly → fused train-step HLO on PJRT — for a few
//! hundred steps, logs the loss curve, and evaluates on held-out seeds.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use pyg2::coordinator::{default_loader, seed_accuracy, TrainConfig, Trainer};
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::runtime::Engine;

fn main() -> pyg2::Result<()> {
    pyg2::util::logging::init();
    let engine = Engine::load("artifacts")?;
    let b = engine.manifest().bucket.clone();

    // Cora-like: 2708 nodes, 7 classes, community-correlated features.
    let graph = sbm::generate(&SbmConfig {
        num_nodes: 2708,
        num_blocks: b.c,
        feature_dim: b.f,
        feature_signal: 1.2,
        seed: 1,
        ..Default::default()
    })?;
    println!(
        "graph: {} nodes, {} edges, {} classes",
        graph.num_nodes(),
        graph.num_edges(),
        graph.num_classes()
    );

    // Train/val split over seed nodes.
    let train_seeds: Vec<u32> = (0..2048).collect();
    let val_seeds: Vec<u32> = (2048..2688).collect();
    let loader = default_loader(&engine, &graph, train_seeds, 2);
    let val_loader = default_loader(&engine, &graph, val_seeds, 1);

    let trainer = Trainer::new(
        &engine,
        TrainConfig { arch: "gcn".into(), epochs: 10, log_every: 0, ..Default::default() },
    );
    println!("training gcn (compiled mode) for 10 epochs = {} steps ...", loader.num_batches() * 10);
    let report = trainer.train(&loader)?;

    // Loss curve (subsampled).
    println!("\nloss curve:");
    let every = (report.history.len() / 16).max(1);
    for r in report.history.iter().step_by(every) {
        let bar = "#".repeat((r.loss * 25.0) as usize);
        println!("  step {:>4}  loss {:.4}  acc {:.3}  {}", r.step, r.loss, r.accuracy, bar);
    }
    println!(
        "\ntrained {} steps in {:.1}s ({:.2} ms/step), final train acc {:.3}",
        report.history.len(),
        report.total_seconds,
        report.mean_step_ms(),
        report.recent_accuracy(8),
    );

    // Held-out evaluation through the inference artifact.
    let mut correct = 0.0;
    let mut batches = 0.0;
    for batch in val_loader.iter_epoch(0) {
        let batch = batch?;
        let inputs = Engine::infer_inputs(&batch);
        let out = engine.run_fused("gcn_infer", &report.final_params.values(), &inputs)?;
        correct += seed_accuracy(&out[0], &batch)?;
        batches += 1.0;
    }
    let val_acc = correct / batches;
    println!("validation accuracy (held-out seeds): {:.3}", val_acc);
    assert!(val_acc > 0.5, "quickstart should comfortably beat 7-class chance");
    println!("quickstart OK");
    Ok(())
}
