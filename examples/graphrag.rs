//! GraphRAG demo (§3.2, Figure 4): knowledge-graph question answering
//! with structure-aware retrieval vs a text-similarity-only baseline,
//! plus the TXT2KG ingestion path.
//!
//! Run: `cargo run --release --example graphrag`.

use pyg2::datasets::kgqa::{self, KgqaConfig};
use pyg2::rag::{GraphRag, Txt2Kg};
use pyg2::runtime::Engine;

fn main() -> pyg2::Result<()> {
    pyg2::util::logging::init();
    let engine = Engine::load("artifacts")?;

    // TXT2KG: unstructured text -> triples (the ingestion front door).
    let mut kg = Txt2Kg::new();
    kg.ingest(
        "the capital of france is paris. the capital of japan is tokyo. \
         paris hosts louvre. tokyo hosts skytree. france borders spain.",
    );
    println!("TXT2KG ingested {} triples from free text", kg.num_triples());
    println!("  query(capital of france) = {:?}", kg.query("france", "capital"));

    // KGQA benchmark: 2-hop questions over a synthetic KG.
    let ds = kgqa::generate(&KgqaConfig {
        num_entities: 500,
        num_questions: 150,
        seed: 4,
        ..Default::default()
    })?;
    println!(
        "\nKGQA: {} entities, {} triples, {} two-hop questions",
        ds.num_entities,
        ds.triples.len(),
        ds.questions.len()
    );

    let rag = GraphRag::new(&engine, &ds)?;
    let (mut rag_hits, mut base_hits) = (0usize, 0usize);
    for q in &ds.questions {
        if rag.answer(&q.text)? == Some(q.answer) {
            rag_hits += 1;
        }
        if rag.baseline_answer(&q.text) == Some(q.answer) {
            base_hits += 1;
        }
    }
    let n = ds.questions.len() as f64;
    let base_acc = 100.0 * base_hits as f64 / n;
    let rag_acc = 100.0 * rag_hits as f64 / n;
    println!("\n  text-similarity baseline (agentic-RAG analog): {base_acc:.1}%");
    println!("  GraphRAG (retrieval + GNN scorer HLO):          {rag_acc:.1}%");
    println!(
        "  (paper reports 16% -> 32% on WebQSP with a trained G-Retriever; \
         the shape — structure-aware retrieval winning by >=2x — is the claim under test)"
    );

    // Show one worked example.
    let q = &ds.questions[0];
    println!("\nworked example:");
    println!("  Q: {}", q.text);
    let sub = rag.retrieve(q.anchor);
    println!("  retrieved subgraph: {} nodes, {} edges", sub.nodes.len(), sub.row.len());
    println!(
        "  predicted: {:?}   ground truth: {}",
        rag.answer(&q.text)?.map(|e| ds.entity_names[e as usize].clone()),
        ds.entity_names[q.answer as usize]
    );

    assert!(rag_acc >= 2.0 * base_acc.max(2.0), "GraphRAG must at least double the baseline");
    println!("graphrag OK");
    Ok(())
}
